"""Batched page flushing — the engine's page-side front end.

Callers no longer flush pages synchronously against the
:class:`~repro.core.pageflush.PageStore`; they :meth:`~FlushQueue.enqueue`
dirty pages and the queue drains them once per *epoch*. The epoch drain

* coalesces: multiple enqueues of the same page merge (latest page image
  wins, dirty-line sets union), so a page written ten times between
  epochs is flushed once;
* partitions the batch round-robin over up to ``lanes`` flush lanes and
  runs each page's flush under :meth:`repro.core.pmem.PMem.lane`, so the
  cost model sees the lanes as concurrent writers;
* drives the Hybrid µLog-vs-CoW crossover with the *actual* number of
  concurrently-active lanes in this epoch (``min(lanes, len(batch))``),
  not a constructor constant — the Fig. 5 crossover moves from ≈119
  dirty lines at 1 lane to ≈31 at 7 because concurrent small writes
  defeat the device's write-combining buffer (Fig. 2).

A custom ``flush_fn(pid, page, dirty_lines, active_lanes)`` replaces the
default ``store.flush`` for callers with their own protocol on top (the
checkpoint manager's shadow-slot deltas).

With a :class:`repro.tier.SpillScheduler` attached (``spill=``), the
epoch drain is also where the SSD tier gets fed: before flushing, cold
PMem slots are evicted to SSD until the batch fits (the *low watermark*
keeps slack beyond the bare minimum), and a mid-batch ``no free slots``
condition evicts and retries instead of failing the epoch — an epoch
that misses the PMem capacity budget overflows asynchronously (off the
caller's critical path) instead of raising.

The queue is also the write-back path of the DRAM buffer manager
(:class:`repro.cache.BufferManager`): dirty frames are enqueued here —
:meth:`BufferManager.writeback <repro.cache.BufferManager.writeback>`
drains them as one epoch, and a clock-evicted dirty frame *parks* its
image in the pending set until that drain. The pending set is DRAM, so
reads may be served from it (:meth:`pending_image`) without adding a
durability point.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.costmodel import COST_MODEL, PMemCostModel

__all__ = ["FlushQueue", "EpochReport"]


@dataclasses.dataclass
class EpochReport:
    """Exact counts + modeled wall-clock for one epoch drain."""

    pages: int = 0
    active_lanes: int = 0
    cow: int = 0
    mulog: int = 0
    barriers: int = 0
    blocks_written: int = 0
    modeled_ns: float = 0.0
    #: cold pages evicted to the SSD tier during this epoch
    pages_spilled: int = 0
    #: modeled SSD time of those evictions (drained concurrently with the
    #: PMem lane work in a real system; reported separately, not summed)
    spill_ns: float = 0.0
    #: device (HBM) bytes the save-path scan kernels read to classify and
    #: pack this epoch's pages (noted via :meth:`FlushQueue.note_scan`;
    #: one live-buffer read with the fused flush_pack kernel, up to three
    #: with the staged chain)
    scan_read_bytes: int = 0
    #: modeled device time of that scan traffic (included in modeled_ns)
    scan_ns: float = 0.0


class FlushQueue:
    """Coalescing, lane-partitioned flush queue over a page store."""

    def __init__(self, pages, *, lanes: int = 4, lane_id_base: int = 0,
                 flush_fn: Optional[Callable[..., Optional[str]]] = None,
                 cost_model: PMemCostModel = COST_MODEL,
                 spill=None, placer=None) -> None:
        """Wrap a page store (or :class:`~repro.pool.PagesHandle`).

        Args:
            pages: the store whose pages this queue flushes.
            lanes: maximum concurrent flush lanes per epoch.
            lane_id_base: first lane id for stats attribution.
            flush_fn: optional ``(pid, page, dirty_lines, active_lanes)``
                override of ``store.flush`` (checkpoint shadow slots).
            cost_model: converts the epoch's op-count delta to time.
            spill: optional :class:`repro.tier.SpillScheduler`; evicts
                cold slots to SSD when an epoch outgrows the PMem budget.
            placer: optional :class:`~repro.io.placer.LanePlacer`; each
                epoch's flush lanes then run on CPU sockets near the page
                region's home socket, overflowing to remote sockets only
                past the near capacity (remote lanes pay the Izraelevitz
                far-socket multipliers in ``engine_time_ns``).
        """
        # accepts a PageStore or anything exposing one (PagesHandle)
        self.store = getattr(pages, "store", pages)
        self.lanes = max(1, int(lanes))
        self.lane_id_base = int(lane_id_base)
        self.cost_model = cost_model
        self._flush_fn = flush_fn
        self.spill = spill
        self.placer = placer
        # pid -> (latest page image, dirty line set | None=all dirty)
        self._pending: Dict[int, Tuple[np.ndarray, Optional[Set[int]]]] = {}
        # HBM bytes the save-path scan read for the pages now pending
        self._scan_bytes = 0

    def __len__(self) -> int:
        return len(self._pending)

    def enqueue(self, pid: int, page: np.ndarray,
                dirty_lines: Optional[Sequence[int]] = None, *,
                copy: bool = True, touch: bool = True) -> None:
        """Queue a page for the next epoch; re-enqueueing merges (latest
        image wins, dirty sets union). The image is copied by default so
        the caller may keep mutating its buffer; ``copy=False`` hands
        ownership of ``page`` to the queue (the checkpoint path builds a
        throwaway array per page — the whole epoch's page set is held
        until the drain, so avoiding the extra copy halves that spike).
        ``touch=False`` suppresses the spill-LRU touch — the buffer
        manager counts each logical access exactly once itself, and its
        write-back enqueues must not disturb the recency order (a
        frameless run would not see them)."""
        page = (np.array(page, dtype=np.uint8, copy=True) if copy
                else np.asarray(page, dtype=np.uint8)).ravel()
        if self.spill is not None and touch:
            # enqueue = recent use (LRU signal, attributed to OUR store)
            self.spill.touch(int(pid), self.store)
        prev = self._pending.get(int(pid))
        if prev is not None and prev[1] is not None and dirty_lines is not None:
            dirty: Optional[Set[int]] = prev[1] | set(int(i) for i in dirty_lines)
        elif prev is not None and (prev[1] is None or dirty_lines is None):
            dirty = None
        else:
            dirty = set(int(i) for i in dirty_lines) if dirty_lines is not None else None
        self._pending[int(pid)] = (page, dirty)

    def note_scan(self, nbytes: int) -> None:
        """Record device (HBM) bytes the save-path scan kernels read on
        behalf of pages being enqueued for the next epoch. The next
        :meth:`flush_epoch` folds the accumulated traffic into its
        modeled time (``engine_time_ns(scan_read_bytes=…)``) and reports
        it on :class:`EpochReport` — the fused flush_pack pass notes each
        live buffer once, the staged chain notes every extra pass."""
        self._scan_bytes += int(nbytes)

    # ------------------------------------------------- buffer-manager hooks

    def pending_image(self, pid: int
                      ) -> Optional[Tuple[np.ndarray, Optional[Set[int]]]]:
        """The coalesced ``(page, dirty)`` queued for ``pid``, or ``None``.
        The pending set is DRAM, so the buffer manager serves reads from
        it (a parked dirty eviction is still the page's newest image)."""
        return self._pending.get(int(pid))

    def pop_pending(self, pid: int
                    ) -> Optional[Tuple[np.ndarray, Optional[Set[int]]]]:
        """Remove and return ``pid``'s queued entry — the buffer manager
        re-adopts a parked image into a frame before writing to it."""
        return self._pending.pop(int(pid), None)

    def pending_pids(self) -> List[int]:
        """Queued pids in first-enqueued (drain) order."""
        return list(self._pending)

    def flush_epoch(self) -> EpochReport:
        """Drain the queue: flush every pending page, lane-partitioned.

        Returns exact counts for the epoch plus the modeled wall-clock
        under ``engine_time_ns`` (burst curve — page flushes are large
        sequential writes, Fig. 5(b))."""
        scan_bytes, self._scan_bytes = self._scan_bytes, 0
        scan_ns = self.cost_model.scan_read_ns(scan_bytes)
        if not self._pending:
            # an all-clean save still paid the scan that proved it clean
            return EpochReport(scan_read_bytes=scan_bytes, scan_ns=scan_ns,
                               modeled_ns=scan_ns)
        items = list(self._pending.items())
        self._pending.clear()
        active = max(1, min(self.lanes, len(items)))
        pm = self.store.pmem
        # NUMA: run every flush lane near the page region's home socket,
        # overflowing to remote CPU sockets only past the near capacity
        home = pm.home_socket(self.store.layout.base)
        if self.placer is not None:
            lane_cpu = self.placer.place([home] * active)
        else:
            lane_cpu = [home] * active
        before = pm.stats.snapshot()
        ssd_before = (self.spill.ssd.stats.snapshot()
                      if self.spill is not None else None)
        rep = EpochReport(pages=len(items), active_lanes=active)
        protect = {pid for pid, _ in items}
        new_pages = sum(1 for pid in protect if pid not in self.store.table)
        if self.spill is not None and new_pages:
            # feed the SSD tier BEFORE touching PMem: evict cold slots so
            # the batch's NET slot demand fits (first-time pages consume a
            # slot permanently; a resident page's CoW is net zero and the
            # +1 covers its transient double-occupancy). An epoch of pure
            # re-flushes triggers no eviction at all.
            rep.pages_spilled += self.spill.ensure_slots(
                self.store, need=new_pages + 1, protect=protect)
        for j, (pid, (page, dirty)) in enumerate(items):
            lines = None if dirty is None else sorted(dirty)
            with pm.lane(self.lane_id_base + (j % active),
                         socket=lane_cpu[j % active]):
                try:
                    if self._flush_fn is not None:
                        tech = self._flush_fn(pid, page, lines, active)
                    else:
                        tech = self.store.flush(pid, page, dirty_lines=lines,
                                                threads=active)
                except RuntimeError:
                    if self.spill is None:
                        raise
                    # mid-batch slot exhaustion (CoW retiring slower than
                    # allocating): evict and retry once. Here eviction MAY
                    # take a batch member (already-flushed ones are cold
                    # and perfectly spillable) — a batch larger than the
                    # whole slot budget has to cycle through itself.
                    rep.pages_spilled += self.spill.ensure_slots(
                        self.store, need=1, protect=protect,
                        allow_protected=True)
                    if self._flush_fn is not None:
                        tech = self._flush_fn(pid, page, lines, active)
                    else:
                        tech = self.store.flush(pid, page, dirty_lines=lines,
                                                threads=active)
            if tech == "mulog":
                rep.mulog += 1
            elif tech is not None:
                rep.cow += 1
        if self.spill is not None:
            rep.spill_ns = self.spill.ssd_cost.time_ns(
                self.spill.ssd.stats.delta(ssd_before))
        delta = pm.stats.delta(before)
        rep.barriers = delta.barriers
        rep.blocks_written = delta.blocks_written
        rep.scan_read_bytes = scan_bytes
        rep.scan_ns = scan_ns
        rep.modeled_ns = self.cost_model.engine_time_ns(
            delta, active_lanes=active, burst=True,
            scan_read_bytes=scan_bytes)
        return rep
