"""The spill scheduler — PMem→SSD eviction, promotion, and recovery.

One :class:`SpillScheduler` owns one pool's flash tier: the
:class:`~repro.core.ssd.SSD` device, the SSD *arena* regions it
bump-allocates extents from (``KIND_SSD`` directory records), and the
durable **spill map** that makes every spilled object reachable after a
crash. Two object kinds spill:

* **cold page slots** — a :class:`~repro.io.flushq.FlushQueue` epoch
  that outgrows the PMem slot budget evicts least-recently-touched
  pages: the slot's durable bytes go to an SSD extent, a checksummed map
  record commits (one Zero-log barrier), and only then is the PMem slot
  header invalidated and freed. Access promotes the page back
  (:meth:`read_page`), CoW-ing it into a PMem slot with a version number
  strictly above its SSD history, then tombstoning the map record.
* **sealed WAL generations** — :meth:`MultiLog.roll
  <repro.io.multilog.MultiLog.roll>` enqueues the sealed generation
  here; :meth:`drain` serializes its entries to an extent, flushes the
  device, commits the map record, and only then advances the log's
  durable retired watermark. The watermark is what recovery consults,
  so a crash mid-spill recovers the generation wholly from PMem (not yet
  retired) or wholly from SSD (retired) — **never both**, and never a
  partial spill (the map record, which locates the SSD copy, is only
  committed after the device flush).

The ordering discipline throughout is *down-tier first*: SSD bytes →
SSD flush → PMem map record → PMem source invalidation. Every crash
window leaves either two identical copies (resolved by preferring the
PMem version at equal-or-higher pvn / an unretired watermark) or one.

The spill map itself is double-buffered: records append to one of two
Zero logs (``<name>.map0/1``) selected by a ping-pong head
(``<name>.mhd``); when the active log fills, the live records are
written to the other log and the head flips atomically.

"Async" here means what it means everywhere in this codebase: spill
work runs at *epoch* boundaries (a flush-queue drain, a checkpoint),
off the application's critical path, and its modeled SSD time is
reported separately so the cost model can overlap it with PMem lane
work. The simulator executes it inline.
"""

from __future__ import annotations

import struct
import zlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.costmodel import SSD_COST_MODEL, SSDCostModel
from repro.core.persist import FlushKind
from repro.core.ssd import SSD, SSDStats

__all__ = ["SpillScheduler", "SpillStats"]

# map record types
_REC_PAGE = 1        # page spilled:   owner, pid, pvn, ssd_off, length, crc
_REC_PAGE_BACK = 2   # page promoted:  owner, pid, pvn
_REC_GEN = 3         # generation spilled: owner, gen, ssd_off, length,
                     #                     entry count, crc

_PAGE = struct.Struct("<IQQII")    # pid, pvn, ssd_off, length, crc
_PAGE_BACK = struct.Struct("<IQ")  # pid, pvn
_GEN = struct.Struct("<QQIII")     # gen, ssd_off, length, count, crc
_MHD = struct.Struct("<QI")        # counter, active map index
_U32 = struct.Struct("<I")

#: default SSD arena region size
DEFAULT_ARENA_BYTES = 1 << 22


class SpillStats:
    """Monotonic spill-activity counters (volatile; the durable truth is
    the spill map)."""

    def __init__(self) -> None:
        self.pages_spilled = 0
        self.pages_promoted = 0
        self.generations_spilled = 0
        self.map_compactions = 0


class SpillScheduler:
    """Eviction/promotion scheduler for one pool's SSD tier.

    Construction opens (or creates) the durable spill map and replays it;
    page stores are then registered with :meth:`attach_pages` and
    generational logs with :meth:`MultiLog.attach_spill
    <repro.io.multilog.MultiLog.attach_spill>`. The scheduler is safe to
    re-open on a recovered pool: everything it needs is in the map and
    the directory.

        pool = Pool.create(None, 1 << 24)
        pool.attach_ssd(SSD(1 << 26))
        sp = SpillScheduler(pool, name="sp")
        pages = pool.pages("heap", npages=256, page_size=4096, nslots=32)
        sp.attach_pages(pages)
        fq = pages.flush_queue(lanes=4)
        fq.spill = sp                      # or FlushQueue(..., spill=sp)
    """

    def __init__(self, pool, ssd: Optional[SSD] = None, *,
                 name: str = "spill",
                 low_watermark: float = 0.25,
                 arena_bytes: int = DEFAULT_ARENA_BYTES,
                 map_capacity: int = 1 << 16,
                 keep_generations: int = 8,
                 arena_socket: int = 0,
                 ssd_cost: SSDCostModel = SSD_COST_MODEL) -> None:
        """Open-or-create the scheduler's durable state on ``pool``.

        Args:
            pool: the :class:`repro.pool.Pool` whose consumers spill.
            ssd: flash device; attached to the pool if not already
                (``None`` uses the pool's previously attached device).
            name: prefix for the scheduler's regions (``<name>.mhd``,
                ``<name>.map0/1`` in PMem; ``<name>.sx<i>`` on SSD) —
                keep it short, region names cap at 20 bytes.
            low_watermark: fraction of a store's slots kept free beyond
                the immediate need when evicting (eviction slack, so
                each epoch does not immediately re-trigger a drain).
            arena_bytes: size of each SSD arena region; a new arena is
                allocated (a directory entry) when the current one fills.
            map_capacity: bytes per spill-map log; the map compacts into
                its double buffer when full.
            keep_generations: retired WAL generations kept reachable per
                log (newest first). Older archive records are pruned at
                the next spill so the map's live set stays bounded — the
                *correctness* tier for a generation is always the
                watermark, this only bounds how far back the SSD archive
                reaches.
            arena_socket: NUMA home socket for arenas this scheduler
                *creates* (existing arenas keep their directory-recorded
                home). The cache's fill-socket accounting reads it back
                via :meth:`fill_socket`.
            ssd_cost: converts the device's op counts to modeled time.
        """
        self.pool = pool
        if ssd is not None:
            pool.attach_ssd(ssd)
        if pool.ssd_dev is None:
            raise ValueError(
                "SpillScheduler needs a flash device: pass ssd= or call "
                "pool.attach_ssd(SSD(...)) first")
        self.ssd: SSD = pool.ssd_dev
        self.name = name
        self.low_watermark = float(low_watermark)
        self.arena_bytes = int(arena_bytes)
        self.keep_generations = int(keep_generations)
        self.arena_socket = int(arena_socket)
        self.ssd_cost = ssd_cost
        self.stats = SpillStats()
        #: test-only failpoint hook: called with a protocol point name;
        #: raising aborts mid-protocol exactly like a crash would
        self.failpoints = None
        #: promotion admission policy: ``(owner, pid) -> bool`` consulted
        #: before any on-access promotion. ``None`` = promote on first
        #: access (the legacy behavior). A ``repro.cache.BufferManager``
        #: registers its k-touch counter here, so every consumer —
        #: including direct ``read_page(promote=True)`` callers —
        #: inherits the same policy.
        self.admission = None
        #: mid-flush guard: ``(owner, pid) -> bool``; pages reported
        #: pinned are not eviction victims in :meth:`ensure_slots`'s
        #: normal pass (the buffer manager pins a frame for the duration
        #: of its write-back epoch). The ``allow_protected`` retry may
        #: still take them — same rule as the epoch's own batch.
        self.pin_guard = None
        #: post-eviction hook ``(owner, pid)`` called after *every* page
        #: eviction, in addition to the per-owner ``on_evict`` callbacks
        #: (the buffer manager resets its admission count there)
        self.on_page_evict = None

        cl = pool.geometry.cache_line
        self._mhd = pool.raw(f"{name}.mhd", nbytes=2 * cl)
        self._maps = []
        for j in (0, 1):
            rname = f"{name}.map{j}"
            if pool.directory.lookup(rname) is not None:
                self._maps.append(pool.log(rname))   # durable record decides
            else:
                self._maps.append(pool.log(rname, capacity=int(map_capacity),
                                           technique="zero"))
        self._mhd_counter, self._active_map = self._read_mhd()

        # durable state, replayed from the active map log
        self._page_map: Dict[Tuple[str, int], Tuple[int, int, int, int]] = {}
        self._gen_map: Dict[Tuple[str, int], Tuple[int, int, int, int]] = {}
        for raw in self._maps[self._active_map].recovered.entries:
            self._replay(bytes(raw))

        # SSD arenas (KIND_SSD regions <name>.sx<i>) + bump pointer
        self._arenas = []
        i = 0
        while pool.directory.lookup(f"{name}.sx{i}") is not None:
            self._arenas.append(pool.ssd_region(f"{name}.sx{i}"))
            i += 1
        self._bump = 0
        for off, length, *_ in list(self._page_map.values()) + list(
                self._gen_map.values()):
            self._bump = max(self._bump, off + length)
        for a in self._arenas:
            self._bump = max(self._bump, a.base)
        # Extents whose page was promoted (tombstoned) or re-spilled are
        # reusable: no live map record references them, and the record
        # that superseded them was durably committed BEFORE they were
        # freed, so reuse is crash-safe. The list is volatile but
        # RECONSTRUCTIBLE: the replayed map is the complete live set, so
        # on (re)open every arena byte below the bump pointer that no
        # live record covers is a hole a previous run leaked — free it.
        # (Records pruned from the archive tail are only reclaimed this
        # way once a compaction durably drops them from the map; until
        # then the stale replayed record keeps the extent conservatively
        # live. Durable *compaction* of the arenas themselves remains
        # open — see ROADMAP.)
        self._free_extents: List[Tuple[int, int]] = []
        self._rebuild_free_extents()

        # volatile: registered stores, LRU clock, queued generation spills
        self._stores: Dict[int, Tuple[str, object]] = {}
        self._on_evict: Dict[str, object] = {}
        self._clock = 0
        self._last_use: Dict[Tuple[str, int], int] = {}
        self._genq: List[Tuple[object, int]] = []

    # ------------------------------------------------------------ wiring

    def attach_pages(self, pages, name: Optional[str] = None,
                     on_evict=None) -> None:
        """Register a page store (or :class:`~repro.pool.PagesHandle`) so
        its pages can spill. ``name`` keys the store's map records and
        defaults to the handle's region name. ``on_evict(pid)``, if
        given, is called after each eviction — consumers with their own
        per-page bookkeeping (the checkpoint manager's shadow slots) use
        it to drop state that referenced the freed slot."""
        store = getattr(pages, "store", pages)
        owner = name if name is not None else getattr(pages, "name", None)
        if owner is None:
            raise ValueError("attach_pages needs a PagesHandle or an "
                             "explicit name= for a bare PageStore")
        self._stores[id(store)] = (owner, store)
        if on_evict is not None:
            self._on_evict[owner] = on_evict
        # Seed the store's pvn floors from the map: a page whose version
        # history continued on SSD must re-enter PMem strictly above it
        # (recovery resolves tiers by max pvn).
        for (o, pid), (_, _, pvn, _) in self._page_map.items():
            if o == owner:
                store.pvn_floor[pid] = max(store.pvn_floor.get(pid, 0), pvn)

    def _owner_of(self, store) -> str:
        try:
            return self._stores[id(store)][0]
        except KeyError:
            raise ValueError(
                "page store is not registered with this scheduler; call "
                "attach_pages(handle) first") from None

    def touch(self, pid: int, store=None) -> None:
        """Record recent use of a page (LRU signal). With a single
        registered store the store argument may be omitted."""
        owner = (self._owner_of(store) if store is not None
                 else next(iter(self._stores.values()), ("?",))[0])
        self._clock += 1
        self._last_use[(owner, int(pid))] = self._clock

    # ----------------------------------------------------------- failpoint

    def _fp(self, point: str) -> None:
        if self.failpoints is not None:
            self.failpoints(point)

    # ------------------------------------------------------------ spill map

    def _read_mhd(self) -> Tuple[int, int]:
        img = self._mhd.durable_view()
        cl = self.pool.geometry.cache_line
        best = (0, 0)
        for slot in range(2):
            counter, active = _MHD.unpack_from(img, slot * cl)
            if counter > best[0]:
                best = (counter, active)
        return best

    def _write_mhd(self, active: int) -> None:
        self._mhd_counter += 1
        slot = self._mhd_counter % 2
        cl = self.pool.geometry.cache_line
        self._mhd.store(slot * cl, _MHD.pack(self._mhd_counter, active),
                        streaming=True)
        self._mhd.persist(slot * cl, _MHD.size, kind=FlushKind.NT)
        self._active_map = active

    @staticmethod
    def _encode(rtype: int, owner: str, body: bytes) -> bytes:
        ob = owner.encode("utf-8")
        return bytes([rtype, len(ob)]) + ob + body

    def _replay(self, raw: bytes) -> None:
        rtype, olen = raw[0], raw[1]
        owner = raw[2 : 2 + olen].decode("utf-8")
        body = raw[2 + olen :]
        if rtype == _REC_PAGE:
            pid, pvn, off, length, crc = _PAGE.unpack_from(body)
            self._page_map[(owner, pid)] = (off, length, pvn, crc)
        elif rtype == _REC_PAGE_BACK:
            pid, pvn = _PAGE_BACK.unpack_from(body)
            cur = self._page_map.get((owner, pid))
            if cur is not None and pvn >= cur[2]:
                del self._page_map[(owner, pid)]
        elif rtype == _REC_GEN:
            gen, off, length, count, crc = _GEN.unpack_from(body)
            self._gen_map[(owner, gen)] = (off, length, count, crc)

    def _map_append(self, raw: bytes) -> None:
        """Durably append one map record (one Zero-log barrier),
        compacting into the double buffer when the active log fills."""
        try:
            self._maps[self._active_map].append(raw)
        except RuntimeError:
            self._compact_map()
            try:
                self._maps[self._active_map].append(raw)
            except RuntimeError:
                raise RuntimeError(
                    f"spill map {self.name!r} cannot hold its live record "
                    f"set even after compaction ({len(self._page_map)} "
                    f"pages + {len(self._gen_map)} generations); create "
                    f"the scheduler with a larger map_capacity") from None
        self._replay(raw)

    def _compact_map(self) -> None:
        """Rewrite the live records into the inactive map log, then flip
        the ping-pong head (one barrier — the atomic switch; a crash
        before it recovers the old map, after it the new one)."""
        other = 1 - self._active_map
        self._maps[other].reset()
        try:
            for (owner, pid), (off, length, pvn, crc) in self._page_map.items():
                self._maps[other].append(self._encode(
                    _REC_PAGE, owner, _PAGE.pack(pid, pvn, off, length, crc)))
            for (owner, gen), (off, length, count, crc) in self._gen_map.items():
                self._maps[other].append(self._encode(
                    _REC_GEN, owner, _GEN.pack(gen, off, length, count, crc)))
        except RuntimeError:
            raise RuntimeError(
                f"spill map {self.name!r} cannot hold its live record set "
                f"({len(self._page_map)} pages + {len(self._gen_map)} "
                f"generations); create the scheduler with a larger "
                f"map_capacity") from None
        self._write_mhd(other)
        self.stats.map_compactions += 1

    # --------------------------------------------------------- SSD extents

    def _rebuild_free_extents(self) -> None:
        """Rebuild the extent free-list from the durable spill map: every
        arena byte below the bump pointer not covered by a live map
        record is reusable. Run at (re)open, this reclaims the holes a
        previous process run tombstoned or superseded but could only
        leak (the free list used to be rebuilt-by-use only) — a
        long-lived tiered engine's SSD footprint now survives reopen
        proportional to its live set plus the archive tail."""
        live = sorted(
            (off, length)
            for off, length, *_ in list(self._page_map.values())
            + list(self._gen_map.values()))
        self._free_extents = []
        li = 0
        for a in sorted(self._arenas, key=lambda a: a.base):
            end = min(a.base + a.length, self._bump)
            pos = a.base
            while li < len(live) and live[li][0] < end:
                off, length = live[li]
                if off + length <= pos:
                    li += 1
                    continue
                if off > pos:
                    self._free_extents.append((pos, off - pos))
                pos = max(pos, off + length)
                li += 1
            if pos < end:
                self._free_extents.append((pos, end - pos))

    def _alloc(self, nbytes: int) -> int:
        """Allocate an SSD extent: reuse a freed one when it fits, else
        bump-allocate, growing the arena set (a new ``KIND_SSD``
        directory region) when the current arenas run out."""
        nbytes = max(1, int(nbytes))
        for i, (off, ln) in enumerate(self._free_extents):
            if ln >= nbytes:
                del self._free_extents[i]
                if ln > nbytes:
                    self._free_extents.append((off + nbytes, ln - nbytes))
                return off
        for a in self._arenas:
            if self._bump >= a.base and self._bump + nbytes <= a.base + a.length:
                off = self._bump
                self._bump += nbytes
                return off
        size = max(self.arena_bytes, nbytes)
        arena = self.pool.ssd_region(f"{self.name}.sx{len(self._arenas)}",
                                     nbytes=size, socket=self.arena_socket)
        self._arenas.append(arena)
        off = arena.base
        self._bump = off + nbytes
        return off

    # ----------------------------------------------------------- page side

    def ensure_slots(self, store, need: int = 1,
                     protect: Iterable[int] = (),
                     allow_protected: bool = False) -> int:
        """Evict cold pages until ``store`` has ``need`` free slots (plus
        the low-watermark slack). Pages in ``protect`` (the epoch's own
        batch) are not victims — unless ``allow_protected`` is set, which
        the flush queue's mid-batch retry uses when CoW genuinely found
        no slot (a batch larger than the whole budget has to cycle
        through its own members). Returns the number of pages evicted;
        stops early once only protected pages remain (without the
        override) or the store is empty."""
        owner = self._owner_of(store)
        protected: Set[int] = {int(p) for p in protect}
        if self.pin_guard is not None:
            # the buffer manager's mid-flush guard: a page whose DRAM
            # frame is pinned (its image is inside a write-back epoch) is
            # not a victim — same standing as the epoch's own batch
            protected |= {pid for pid in store.table
                          if self.pin_guard(owner, pid)}
        slack = int(self.low_watermark * store.layout.nslots)
        target = min(int(need) + slack, store.layout.nslots)
        evicted = 0
        while len(store.free) < target:
            victims = [pid for pid in store.table if pid not in protected]
            if not victims:
                break
            victim = min(victims,
                         key=lambda p: self._last_use.get((owner, p), 0))
            self._evict_page(owner, store, victim)
            evicted += 1
        if allow_protected:
            hard = min(int(need), store.layout.nslots)
            while len(store.free) < hard and store.table:
                victim = min(store.table,
                             key=lambda p: self._last_use.get((owner, p), 0))
                self._evict_page(owner, store, victim)
                evicted += 1
        return evicted

    def _evict_page(self, owner: str, store, pid: int) -> None:
        """Spill one page: SSD bytes → flush → map record → release the
        PMem slot. See the module docstring for the crash argument."""
        layout = store.layout
        slot, pvn = store.table[pid]
        data = store.pmem.load(layout.slot_data_off(slot), layout.page_size,
                               uncached=True)
        prev = self._page_map.get((owner, pid))   # re-spill supersedes this
        off = self._alloc(layout.page_size)
        self.ssd.pwrite(off, data)
        self._fp("page:ssd_written")
        self.ssd.flush()
        self._fp("page:ssd_flushed")
        crc = zlib.crc32(data.tobytes()) & 0xFFFFFFFF
        self._map_append(self._encode(
            _REC_PAGE, owner, _PAGE.pack(pid, pvn, off, layout.page_size,
                                         crc)))
        self._fp("page:mapped")
        if prev is not None:
            # the new record durably superseded the old extent — reusable
            self._free_extents.append((prev[0], prev[1]))
        store.release(pid)
        store.pvn_floor[pid] = max(store.pvn_floor.get(pid, 0), pvn)
        self._last_use.pop((owner, pid), None)
        self.stats.pages_spilled += 1
        cb = self._on_evict.get(owner)
        if cb is not None:
            cb(pid)
        if self.on_page_evict is not None:
            self.on_page_evict(owner, pid)

    def residency(self, store, pid: int) -> Optional[str]:
        """Which tier holds the page's current version under the
        cross-tier max-pvn rule: ``"pmem"``, ``"ssd"``, or ``None`` when
        the page has never been flushed. The buffer manager's fill path
        routes on this."""
        owner = self._owner_of(store)
        pid = int(pid)
        rec = self._page_map.get((owner, pid))
        if pid in store.table and (rec is None
                                   or store.table[pid][1] >= rec[2]):
            return "pmem"
        return "ssd" if rec is not None else None

    def _arena_socket_of(self, off: int) -> int:
        """Home socket of the arena covering an SSD extent offset (the
        directory-recorded region home; 0 if no arena covers it)."""
        for a in self._arenas:
            if a.base <= off < a.base + a.length:
                return a.record.socket
        return 0

    def fill_socket(self, store, pid: int) -> int:
        """The NUMA home socket a cache fill for this page would read
        from: the PMem slot's home-socket tag when PMem-resident, the
        covering SSD arena's region home when spilled, 0 for pages in
        neither tier. The buffer manager tags frames (and counts remote
        fills) with this."""
        owner = self._owner_of(store)
        pid = int(pid)
        tier = self.residency(store, pid)
        if tier == "pmem":
            slot, _ = store.table[pid]
            return store.pmem.home_socket(store.layout.slot_off(slot))
        if tier == "ssd":
            return self._arena_socket_of(self._page_map[(owner, pid)][0])
        return 0

    def read_page(self, store, pid: int, *, promote: bool = True
                  ) -> np.ndarray:
        """Read a page wherever it lives. PMem-resident pages read from
        their slot; spilled ones read from SSD (checksum-verified) and,
        with ``promote=True``, are re-installed in a PMem slot (evicting
        something colder if the store is full) with a version number
        strictly above their SSD history, then tombstoned off the map.

        When an :attr:`admission` policy is registered (the buffer
        manager's k-touch counter), ``promote=True`` is a *request*: the
        policy decides whether this access actually promotes — replacing
        the legacy promote-on-first-access."""
        owner = self._owner_of(store)
        pid = int(pid)
        self.touch(pid, store)
        if promote and self.admission is not None:
            promote = bool(self.admission(owner, pid))
        # cross-tier max-pvn rule (residency): the PMem slot wins at
        # equal pvn (the copies are identical then — the crash landed
        # between the map record and the slot release); a *lower* PMem
        # pvn is a stale durable header the SSD history superseded
        tier = self.residency(store, pid)
        if tier == "pmem":
            return store.read_page(pid)
        if tier is None:
            raise KeyError(f"page {pid} of {owner!r} is in neither tier")
        off, length, pvn, crc = self._page_map[(owner, pid)]
        data = self.ssd.pread(off, length)
        if (zlib.crc32(data.tobytes()) & 0xFFFFFFFF) != crc:
            raise RuntimeError(
                f"page {pid} of {owner!r}: SSD copy fails its checksum "
                f"(torn spill should be unreachable — map records commit "
                f"after the device flush)")
        if promote:
            self.ensure_slots(store, need=1, protect=(pid,))
            store.flush_cow(pid, data, pvn_floor=pvn + 1)
            self._fp("page:promoted")
            self._map_append(self._encode(
                _REC_PAGE_BACK, owner, _PAGE_BACK.pack(pid, pvn)))
            # the durable tombstone released the extent for reuse
            self._free_extents.append((off, length))
            self.stats.pages_promoted += 1
        return data

    def discard_page(self, store, pid: int) -> None:
        """Durably forget a page from *both* tiers — the cross-shard
        invalidation step of a view change (repro.cluster): by the time
        the source engine discards a range, its new owner already holds
        the content durably behind a committed ownership record, so
        ordering within the discard is not a commit point. The SSD copy
        is superseded by the same ``PAGE_BACK`` tombstone a promotion
        writes (the extent is reusable once the tombstone is durable);
        the PMem slot is released through the store's durable
        header-invalidate, with the version floor pinned so a later
        re-migration back cannot resurrect the stale history."""
        owner = self._owner_of(store)
        pid = int(pid)
        rec = self._page_map.pop((owner, pid), None)
        if rec is not None:
            off, length, pvn, _crc = rec
            self._map_append(self._encode(
                _REC_PAGE_BACK, owner, _PAGE_BACK.pack(pid, pvn)))
            self._free_extents.append((off, length))
        if pid in store.table:
            slot_pvn = store.table[pid][1]
            store.release(pid)
            store.pvn_floor[pid] = max(store.pvn_floor.get(pid, 0), slot_pvn)
        self._last_use.pop((owner, pid), None)

    def read_spilled(self, owner: str, pid: int,
                     pvn: Optional[int] = None) -> np.ndarray:
        """Checksum-verified read of a spilled page *by owner name*,
        without a registered store — the checkpoint restore path, which
        deliberately verifies manifests before opening the page region.
        ``pvn`` (if given) must match the map record's, so a manifest can
        pin the exact version it committed."""
        rec = self._page_map.get((owner, int(pid)))
        if rec is None:
            raise KeyError(f"page {pid} of {owner!r} is not on SSD")
        off, length, rec_pvn, crc = rec
        if pvn is not None and int(pvn) != rec_pvn:
            raise KeyError(
                f"page {pid} of {owner!r}: SSD holds pvn {rec_pvn}, "
                f"caller pinned pvn {pvn}")
        data = self.ssd.durable_read(off, length)
        if (zlib.crc32(data.tobytes()) & 0xFFFFFFFF) != crc:
            raise RuntimeError(
                f"page {pid} of {owner!r}: SSD copy fails its checksum")
        return data

    def read_spilled_many(self, owner: str,
                          wants: List[Tuple[int, Optional[int]]]
                          ) -> List[np.ndarray]:
        """Batched :meth:`read_spilled`: fetch ``[(pid, pvn), ...]`` in
        one call, returned in request order. The fused restore path uses
        this so a leaf's SSD-resident pages arrive together and the
        whole leaf can be verified+assembled in a single device pass;
        any page that is missing, version-mismatched or corrupt raises
        exactly like the single-page read would."""
        return [self.read_spilled(owner, pid, pvn) for pid, pvn in wants]

    def spilled_pages(self, store=None) -> Dict[int, int]:
        """``{pid: pvn}`` of pages currently mapped to SSD (for one
        registered store, or all owners when ``store`` is ``None``)."""
        if store is None:
            return {pid: rec[2] for (_, pid), rec in self._page_map.items()}
        owner = self._owner_of(store)
        return {pid: rec[2] for (o, pid), rec in self._page_map.items()
                if o == owner}

    # ------------------------------------------------------ generation side

    def enqueue_generation(self, multilog, gen: int) -> None:
        """Queue a sealed WAL generation as a spill candidate (called by
        :meth:`MultiLog.roll`, and by ``attach_spill`` for generations
        recovered sealed-but-unretired). The generation stays
        PMem-resident and recoverable until :meth:`drain` durably
        retires it. Duplicate enqueues coalesce."""
        item = (multilog, int(gen))
        if item not in self._genq:
            self._genq.append(item)

    def drain(self) -> int:
        """Process every queued generation spill: serialize → SSD write →
        device flush → map record → advance the log's retired watermark
        (which re-zeroes the freed ring slot). Returns the number of
        generations retired. Runs at epoch boundaries (a checkpoint, a
        ring-full roll) — never on the append path."""
        done = 0
        queue, self._genq = self._genq, []
        for ml, gen in queue:
            if gen <= ml.retired_upto:
                continue  # already retired (e.g. an earlier forced drain)
            payloads = ml.sealed_generations().get(gen)
            if payloads is None:
                continue
            buf = bytearray(_U32.pack(len(payloads)))
            for p in payloads:
                buf += _U32.pack(len(p)) + p
            blob = bytes(buf)
            off = self._alloc(len(blob))
            self.ssd.pwrite(off, blob)
            self._fp("gen:ssd_written")
            self.ssd.flush()
            self._fp("gen:ssd_flushed")
            crc = zlib.crc32(blob) & 0xFFFFFFFF
            self._map_append(self._encode(
                _REC_GEN, ml.name,
                _GEN.pack(gen, off, len(blob), len(payloads), crc)))
            self._fp("gen:mapped")
            ml.mark_retired(gen)
            self._fp("gen:retired")
            # prune the archive tail so the map's live set stays bounded
            # (the SSD extents of pruned generations leak — the archive is
            # best-effort history, the watermark is the correctness rule)
            floor = gen - self.keep_generations
            for key in [k for k in self._gen_map
                        if k[0] == ml.name and k[1] <= floor]:
                del self._gen_map[key]
            self.stats.generations_spilled += 1
            done += 1
        return done

    def read_generation(self, owner: str, gen: int) -> List[bytes]:
        """Payloads of a retired generation, read back from SSD and
        verified against the map record's checksum and entry count."""
        rec = self._gen_map.get((owner, int(gen)))
        if rec is None:
            raise KeyError(f"generation {gen} of {owner!r} is not on SSD")
        off, length, count, crc = rec
        blob = self.ssd.pread(off, length).tobytes()
        if (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
            raise RuntimeError(
                f"generation {gen} of {owner!r}: SSD copy fails its "
                f"checksum (torn spill should be unreachable — the "
                f"retired watermark advances only after the device flush)")
        (n,) = _U32.unpack_from(blob, 0)
        if n != count:
            raise RuntimeError(f"generation {gen} of {owner!r}: entry "
                               f"count mismatch ({n} != {count})")
        out: List[bytes] = []
        pos = _U32.size
        for _ in range(n):
            (ln,) = _U32.unpack_from(blob, pos)
            pos += _U32.size
            out.append(blob[pos : pos + ln])
            pos += ln
        return out

    # ------------------------------------------------------------- metrics

    @property
    def pending_generations(self) -> int:
        """Sealed generations queued but not yet durably retired."""
        return len(self._genq)

    def modeled_ns(self, delta: SSDStats) -> float:
        """Modeled SSD time for a device op-count delta."""
        return self.ssd_cost.time_ns(delta)
