"""``repro.tier`` — the SSD capacity tier below PMem.

The paper positions PMem *between* DRAM and flash: fast enough to
absorb the I/O critical path, but capacity-constrained, with NAND flash
as the cheap cold tier underneath. This package completes that
hierarchy for the whole stack:

- :mod:`repro.core.ssd`      — the modeled flash device (block-granular,
  write-buffered, crash-simulated) and its exact op counts.
- :class:`~repro.core.costmodel.SSDCostModel` — counts → modeled time
  with the Fig. 1 latency/bandwidth gap and NAND's read/write asymmetry.
- :mod:`repro.tier.spill`    — :class:`SpillScheduler`: evicts cold page
  slots and sealed WAL generations to SSD-backed directory regions
  (``KIND_SSD``), promotes pages back on access, and keeps everything
  reachable across crashes through a checksummed, double-buffered spill
  map.

Wiring: a :class:`~repro.io.flushq.FlushQueue` takes ``spill=`` and
feeds the tier at epoch drains (an epoch that outgrows the PMem slot
budget evicts cold pages instead of failing allocation); a generational
:class:`~repro.io.multilog.MultiLog` enqueues sealed generations at
:meth:`~repro.io.multilog.MultiLog.roll`; and
:class:`~repro.core.recovery.PersistentKV` drives both from its
checkpoint path (``KVConfig(slot_budget=…, wal_lanes=…)``), which is
what lets it run a lane-striped redo log indefinitely in bounded PMem.

Above the scheduler sits the DRAM rung
(:class:`~repro.cache.BufferManager`): it registers its k-touch counter
as the scheduler's ``admission`` policy (on-access promotion then fires
on the k-th touch, not the first), its pinned frames as the
``pin_guard`` honored by :meth:`SpillScheduler.ensure_slots`, and is
told about every slot eviction via ``on_page_evict``. On reopen the
scheduler rebuilds its SSD extent free-list from the durable spill map,
so holes a previous run tombstoned are reusable instead of leaked.
"""

from repro.core.ssd import SSD, SSDStats  # noqa: F401
from repro.tier.spill import SpillScheduler, SpillStats  # noqa: F401
