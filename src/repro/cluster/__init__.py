"""repro.cluster — sharded multi-engine KV with crash-consistent view
changes.

N independent :class:`~repro.core.recovery.PersistentKV` engines (each
its own pool, WAL lanes, spill tier and cache) behind a durable
rendezvous-hashed range map (:class:`ShardMap`), routed by
:class:`ClusterKV`, resharded live by view changes whose per-range
commit point is one durable ownership record — the spill protocol's
down-tier-first ordering generalized to cross-shard handoff (copy →
flush → ownership record → invalidate). Membership policies
(:class:`HeartbeatRegistry`, :class:`BackupStepPolicy`,
:func:`plan_view`) decide which shard set the next view targets.
Proven by ``tests/test_cluster_acceptance.py`` and the
crash-mid-reshard corpus in ``tests/test_crash_corpus.py``.
"""

from repro.cluster.membership import (BackupStepPolicy, HeartbeatRegistry,
                                      plan_view)
from repro.cluster.router import (CausalSession, ClusterConfig, ClusterKV,
                                  ReshardReport, ViewChange)
from repro.cluster.shardmap import ShardMap, rendezvous_owner

__all__ = ["BackupStepPolicy", "CausalSession", "ClusterConfig", "ClusterKV",
           "HeartbeatRegistry", "ReshardReport", "ShardMap", "ViewChange",
           "plan_view", "rendezvous_owner"]
