"""Cluster membership: failure detection and slow-shard cordoning feed
view planning.

A view change needs a target shard set; these policies decide it. Both
are deterministic decision logic over injected clocks/observations —
the part that must be correct — simulated single-process here exactly
like the engines (the transport is jax.distributed in deployment).
They moved here from the seed ``repro.distributed`` modules
(``fault_tolerance``/``straggler``), whose training-specific remainder
(elastic checkpoint assembly, gradient quorum) stays put:

  - :class:`HeartbeatRegistry` — hosts beat; misses past a deadline
    declare them dead. Dead shards should leave the next view.
  - :class:`BackupStepPolicy` — an EWMA straggler detector; persistent
    stragglers are cordoned. Cordoned shards should leave the next
    view before they drag the cluster's p99 with them (Wu
    arXiv:2005.07658: one slow partition sets the tail).
  - :func:`plan_view` — folds both into "the shard set the next
    ``ClusterKV.reshard`` should target".
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Set

import numpy as np

__all__ = ["HeartbeatRegistry", "BackupStepPolicy", "plan_view"]


@dataclasses.dataclass
class HeartbeatRegistry:
    """Deadline-based failure detector: hosts call :meth:`beat`, a
    periodic :meth:`sweep` declares silent ones dead. Death is sticky —
    a late beat from a declared-dead host is ignored (it must rejoin
    through a view change, not un-die)."""

    deadline_s: float = 10.0

    def __post_init__(self) -> None:
        self._last: Dict[int, float] = {}
        self.dead: Set[int] = set()

    def beat(self, host: int, now: Optional[float] = None) -> None:
        """Record a heartbeat (``now`` injects a deterministic clock)."""
        if host in self.dead:
            return
        self._last[host] = time.monotonic() if now is None else now

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Declare hosts silent past the deadline dead; returns the
        newly dead."""
        now = time.monotonic() if now is None else now
        newly = [h for h, t in self._last.items()
                 if h not in self.dead and now - t > self.deadline_s]
        self.dead.update(newly)
        return newly

    @property
    def alive(self) -> List[int]:
        """Hosts that have beaten and are not declared dead."""
        return sorted(h for h in self._last if h not in self.dead)


@dataclasses.dataclass
class BackupStepPolicy:
    """EWMA straggler detector: hosts whose smoothed step time exceeds
    ``threshold ×`` the median are flagged; ``patience`` consecutive
    flags cordon the host (work continues on the survivors via a view
    change). Cordoning is sticky for the policy's lifetime."""

    threshold: float = 1.8       # × median EWMA step time
    patience: int = 3
    ewma: float = 0.3

    def __post_init__(self) -> None:
        self._t: Dict[int, float] = {}
        self._flags: Dict[int, int] = {}
        self.cordoned: Set[int] = set()

    def observe(self, host: int, step_time: float) -> None:
        """Fold one step-time sample into the host's EWMA."""
        prev = self._t.get(host, step_time)
        self._t[host] = (1 - self.ewma) * prev + self.ewma * step_time

    def evaluate(self) -> List[int]:
        """Flag outliers against the median; returns hosts newly
        cordoned this round."""
        active = {h: t for h, t in self._t.items() if h not in self.cordoned}
        if len(active) < 2:
            return []
        med = float(np.median(list(active.values())))
        newly = []
        for h, t in active.items():
            if t > self.threshold * med:
                self._flags[h] = self._flags.get(h, 0) + 1
                if self._flags[h] >= self.patience:
                    self.cordoned.add(h)
                    newly.append(h)
            else:
                self._flags[h] = 0
        return newly


def plan_view(current: Iterable[int],
              registry: Optional[HeartbeatRegistry] = None,
              policy: Optional[BackupStepPolicy] = None) -> List[int]:
    """The shard set the next view change should target: the current
    set minus dead (registry) and cordoned (policy) shards. Feed the
    result to ``ClusterKV.reshard``; raises if nobody survives (a view
    needs at least one shard)."""
    ids = {int(s) for s in current}
    if registry is not None:
        ids -= set(registry.dead)
    if policy is not None:
        ids -= set(policy.cordoned)
    if not ids:
        raise ValueError("no shards left for the next view")
    return sorted(ids)
