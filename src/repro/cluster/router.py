"""``ClusterKV``: N independent engines behind one durable shard map,
with crash-consistent live view changes.

Each shard is a full :class:`~repro.core.recovery.PersistentKV` engine
on its **own pool** — its own WAL lanes, flush queue, spill tier and
DRAM frames — exactly as ``repro.serve`` builds per-tenant engines. The
router owns no data: it routes every ``put``/``get`` by the durable
per-range ownership record in the :class:`~repro.cluster.shardmap.ShardMap`
(on a small dedicated *meta pool*), so "who answers this key" has a
single point of truth at every instant, including mid-reshard.

**Life of a view change** (``reshard``), per moving range, generalizing
the spill protocol's down-tier-first ordering to cross-shard handoff::

    copy   — durable page images + committed WAL records stream from
             the source engine into the target's frames and WAL
    flush  — the target writes the range back and commits its WAL: the
             bytes are durable on the new owner, but unreachable (the
             ownership record still names the old one)
    own    — ONE Zero-log barrier flips the range's ownership record:
             the atomic per-range commit point
    inval  — the source durably discards its copies (frames, parked
             images, PMem slots, SSD extents)

A crash strictly before ``own`` recovers exactly-old-owner (the copy
never mutated the source); at or after it, exactly-new-owner (the
source's leftovers are unreachable and scrubbed at reopen). Never both,
never neither — the crash-corpus invariant. Resuming an interrupted
view change re-runs only the not-yet-flipped ranges (the copy step is
idempotent: it re-ships the same durable cut) and converges.

Migration traffic is charged on the modeled clock: each range's step
prices the PMem/SSD/cache deltas it caused on *both* engines through
``engine_time_ns`` and adds the interconnect term
``cluster_transfer_ns(bytes_moved)`` on the receiving side, so
``benchmarks/cluster_reshard.py`` can race resharding against
foreground traffic deterministically.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.costmodel import COST_MODEL, SSD_COST_MODEL
from repro.core.recovery import KVConfig, PersistentKV, _REC
from repro.cluster.shardmap import ShardMap

__all__ = ["ClusterConfig", "ClusterKV", "CausalSession", "ReshardReport",
           "ViewChange"]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Shape of a sharded KV: the per-shard engine config plus the range
    geometry of the shard map.

    ``kv.npages`` spans the **global** key space (every engine can host
    any page; which pages it actually materializes is decided by
    ownership), carved into ``n_ranges`` equal page-aligned ranges —
    the granule of migration and of ownership records."""

    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    n_ranges: int = 8
    map_capacity: int = 1 << 14

    def __post_init__(self) -> None:
        if self.n_ranges < 1 or self.kv.npages % self.n_ranges:
            raise ValueError(
                f"n_ranges={self.n_ranges} must divide npages="
                f"{self.kv.npages} (ranges are page-aligned)")

    @property
    def pages_per_range(self) -> int:
        """Pages per migration granule."""
        return self.kv.npages // self.n_ranges

    @property
    def nkeys(self) -> int:
        """Global key space size (== the per-engine key space)."""
        return self.kv.nkeys


@dataclasses.dataclass(frozen=True)
class ReshardReport:
    """What one view change did, on the modeled clock.

    ``engine_ns`` is the full modeled cost of the migration steps (PMem
    + SSD + cache work on both sides, interconnect term included);
    ``transfer_ns`` is the interconnect term alone."""

    view: int
    shards: Tuple[int, ...]
    ranges_moved: Tuple[int, ...]
    pages_moved: int
    page_bytes: int
    wal_records_moved: int
    wal_bytes: int
    engine_ns: float
    transfer_ns: float

    @property
    def bytes_moved(self) -> int:
        """Total migration payload: page images + WAL records."""
        return self.page_bytes + self.wal_bytes


class ViewChange:
    """One in-flight view change, migrated range-at-a-time.

    Callers that interleave foreground traffic (the reshard-under-load
    benchmark, a serving loop) drive :meth:`step` themselves; the last
    step commits the view. :meth:`run` drives it to completion."""

    def __init__(self, cluster: "ClusterKV", shards: Iterable[int]) -> None:
        """Durably start the view change toward ``shards`` (re-entrant
        for resume — see ``ShardMap.begin_view``)."""
        ids = tuple(sorted(int(s) for s in shards))
        unknown = set(ids) - set(cluster._engines)
        if unknown:
            raise ValueError(f"no engines for shards {sorted(unknown)}")
        self._c = cluster
        self.view = cluster.map.begin_view(ids)
        cluster._fp("view:started")
        self.target_shards = ids
        self.target = cluster.map.assignment(ids)
        #: ranges still to migrate, in range order (deterministic)
        self.todo: List[int] = cluster.map.moving_ranges(ids)
        self.moved: List[int] = []
        self.pages_moved = 0
        self.page_bytes = 0
        self.wal_records_moved = 0
        self.wal_bytes = 0
        self.engine_ns = 0.0
        self.transfer_ns = 0.0
        self._done = False

    def step(self) -> bool:
        """Migrate the next moving range (commit the view once none
        remain). Returns True while more steps are pending."""
        if self._done:
            return False
        if self.todo:
            r = self.todo.pop(0)
            self._c._migrate_range(r, self.view, self.target[r], self)
            self.moved.append(r)
        if not self.todo:
            self._c._scrub_all()
            self._c.map.commit_view()
            self._c._fp("view:committed")
            self._done = True
            return False
        return True

    def run(self) -> ReshardReport:
        """Drive the view change to completion and report it."""
        while self.step():
            pass
        return self.report()

    def report(self) -> ReshardReport:
        """The migration's byte/time accounting so far."""
        return ReshardReport(
            view=self.view, shards=self.target_shards,
            ranges_moved=tuple(self.moved), pages_moved=self.pages_moved,
            page_bytes=self.page_bytes,
            wal_records_moved=self.wal_records_moved,
            wal_bytes=self.wal_bytes, engine_ns=self.engine_ns,
            transfer_ns=self.transfer_ns)


class CausalSession:
    """A client session with cross-shard causal consistency.

    Within a session, before a write lands on a shard every *other*
    shard holding one of the session's earlier-not-yet-committed writes
    is group-committed first. Each shard's WAL recovers a contiguous
    durable prefix, so after any crash a surviving write implies all its
    causal predecessors survive too — across shards, not just within
    one — which is the acceptance suite's causal-chain invariant.
    Reads go through the owners' frames: read-your-writes for free."""

    def __init__(self, cluster: "ClusterKV") -> None:
        """Bind to a router; sessions are cheap, make one per client."""
        self._c = cluster
        self._uncommitted: set = set()

    def put(self, key: int, value: bytes) -> int:
        """Causally ordered durable upsert (see class docstring)."""
        sid = self._c.owner_of(key)
        for dep in sorted(self._uncommitted - {sid}):
            self._c._commit_shard(dep)
            self._uncommitted.discard(dep)
        lsn = self._c.put(key, value)
        self._uncommitted.add(sid)
        return lsn

    def get(self, key: int) -> bytes:
        """Read through the owning engine's frames."""
        return self._c.get(key)

    def flush(self) -> None:
        """Commit every shard this session still has in flight."""
        for sid in sorted(self._uncommitted):
            self._c._commit_shard(sid)
        self._uncommitted.clear()


class ClusterKV:
    """Sharded PersistentKV: route by durable ownership, reshard live.

    Open-or-create over a meta pool (shard map) plus one pool per shard
    (engines, named ``s<sid>`` on their pool). Tiered configs need each
    shard pool's SSD attached **before** construction. ``shards=``
    restricts the *initial view* to a subset of the provided pools —
    spare pools idle until a reshard pulls them in (the add-shard
    scenario). On reopen the constructor recovers every engine and the
    map, then scrubs non-owner leftovers of every range (frames the
    engines' WAL replay resurrected for keys they no longer own, durable
    copies an interrupted invalidation left behind, and — via a
    checkpoint of any engine whose WAL holds records for ranges it does
    not own — stale WAL residue that would otherwise replay over newer
    page images on a later restart) — reopening is therefore
    self-healing, and resuming an interrupted view change is just
    ``resume()``."""

    def __init__(self, meta_pool, shard_pools: Dict[int, object],
                 cfg: Optional[ClusterConfig] = None, *,
                 shards: Optional[Iterable[int]] = None) -> None:
        """Open-or-create; see the class docstring."""
        cfg = cfg or ClusterConfig()
        self.cfg = cfg
        self.meta_pool = meta_pool
        self._pools = dict(sorted(shard_pools.items()))
        if len({id(p) for p in self._pools.values()}) != len(self._pools):
            raise ValueError("each shard needs its own pool")
        #: test-only failpoint hook — called with a protocol point name;
        #: raising aborts mid-protocol exactly like a crash would
        self.failpoints = None
        recover = meta_pool.directory.lookup("sm.hd") is not None
        ids = tuple(sorted(int(s) for s in (shards if shards is not None
                                            else self._pools)))
        if set(ids) - set(self._pools):
            raise ValueError(f"shards {ids} not all backed by pools")
        self.map = ShardMap(meta_pool, n_ranges=cfg.n_ranges,
                            nkeys=cfg.nkeys, shards=ids,
                            map_capacity=cfg.map_capacity)
        if (self.map.n_ranges, self.map.nkeys) != (cfg.n_ranges, cfg.nkeys):
            raise ValueError(
                f"map geometry ({self.map.n_ranges} ranges, "
                f"{self.map.nkeys} keys) does not match the config "
                f"({cfg.n_ranges}, {cfg.nkeys})")
        self._engines: Dict[int, PersistentKV] = {
            sid: pool.kv(f"s{sid}", cfg.kv)
            for sid, pool in self._pools.items()}
        missing = set(self.map.owners().values()) - set(self._engines)
        if missing:
            raise ValueError(f"map names owners {sorted(missing)} but no "
                             f"pool was provided for them")
        if recover:
            self._scrub_all()

    def pool(self, sid: int):
        """The pmem pool backing shard ``sid`` (for pricing its deltas
        through ``engine_time_ns`` and for test assertions)."""
        return self._pools[int(sid)]

    @classmethod
    def open(cls, meta_pool, shard_pools: Dict[int, object],
             cfg: Optional[ClusterConfig] = None) -> "ClusterKV":
        """Reopen after a restart (same as the constructor on existing
        pools — provided for symmetry with ``PersistentKV.open``)."""
        return cls(meta_pool, shard_pools, cfg)

    # ----------------------------------------------------------- failpoint

    def _fp(self, point: str) -> None:
        if self.failpoints is not None:
            self.failpoints(point)

    # -------------------------------------------------------------- sizing

    @staticmethod
    def shard_pool_bytes(cfg: ClusterConfig) -> int:
        """Pool bytes one shard's engine needs (directory included)."""
        return PersistentKV.region_bytes(cfg.kv) + (1 << 14)

    @staticmethod
    def meta_pool_bytes(cfg: ClusterConfig) -> int:
        """Pool bytes the shard map's meta pool needs."""
        from repro.pool import DEFAULT_MAX_REGIONS, Pool
        g = cfg.kv.geometry
        return (Pool.overhead_bytes(g, DEFAULT_MAX_REGIONS)
                + ShardMap.region_bytes(g, cfg.map_capacity) + (1 << 12))

    # ------------------------------------------------------------- routing

    def range_of(self, key: int) -> int:
        """The page-aligned range a key belongs to."""
        if not (0 <= key < self.cfg.nkeys):
            raise KeyError(key)
        return (key // self.cfg.kv.recs_per_page) // self.cfg.pages_per_range

    def owner_of(self, key: int) -> int:
        """The shard whose durable ownership record answers this key."""
        return self.map.owner_of_range(self.range_of(key))

    def engine(self, sid: int) -> PersistentKV:
        """A shard's engine (tests and benchmarks poke at internals)."""
        return self._engines[sid]

    @property
    def view(self) -> int:
        """Last committed view number."""
        return self.map.view

    @property
    def shards(self) -> Tuple[int, ...]:
        """Shard ids of the committed view."""
        return self.map.shards

    def _range_pids(self, r: int) -> range:
        ppr = self.cfg.pages_per_range
        return range(r * ppr, (r + 1) * ppr)

    # ----------------------------------------------------------------- api

    def put(self, key: int, value: bytes) -> int:
        """Durable upsert on the owning shard; returns its engine LSN."""
        return self._engines[self.owner_of(key)].put(key, value)

    def get(self, key: int) -> bytes:
        """Read from the owning shard — exactly one engine ever answers
        a key under a given map state."""
        return self._engines[self.owner_of(key)].get(key)

    def commit(self) -> None:
        """Group-commit every engine's WAL tail."""
        for sid in sorted(self._engines):
            self._commit_shard(sid)

    def checkpoint(self) -> None:
        """Checkpoint every engine (flush + WAL truncation)."""
        for sid in sorted(self._engines):
            self._engines[sid].checkpoint()

    def session(self) -> CausalSession:
        """A causally consistent client session (see CausalSession)."""
        return CausalSession(self)

    def _commit_shard(self, sid: int) -> None:
        commit = getattr(self._engines[sid].wal, "commit", None)
        if commit is not None:
            commit()

    def digest(self) -> str:
        """sha256 over the committed view, every ownership record and
        every key's current value — the bit-determinism witness the
        acceptance suite compares across identically seeded runs."""
        h = hashlib.sha256()
        h.update(struct.pack("<QI", self.map.view, len(self.map.shards)))
        for sid in self.map.shards:
            h.update(struct.pack("<I", sid))
        for r in range(self.cfg.n_ranges):
            h.update(struct.pack("<II", r, self.map.owner_of_range(r)))
        for key in range(self.cfg.nkeys):
            try:
                h.update(self.get(key))
            except KeyError:
                h.update(b"\x00absent")
        return h.hexdigest()

    # -------------------------------------------------------- view changes

    def begin_reshard(self, shards: Iterable[int]) -> ViewChange:
        """Durably start a view change toward ``shards`` and hand back
        the step-at-a-time driver."""
        return ViewChange(self, shards)

    def reshard(self, shards: Iterable[int]) -> ReshardReport:
        """Run a full view change to ``shards`` (see module docstring
        for the per-range protocol) and report what moved."""
        return self.begin_reshard(shards).run()

    def resume(self) -> Optional[ReshardReport]:
        """Finish a view change a crash interrupted, if any: re-runs the
        not-yet-flipped ranges and commits. Returns None when no view is
        pending."""
        if self.map.pending is None:
            return None
        return self.reshard(self.map.pending[1])

    def _migrate_range(self, r: int, view: int, dst_sid: int,
                       vc: ViewChange) -> None:
        """One range's copy → flush → ownership record → invalidate (the
        module docstring's protocol), priced on the modeled clock."""
        src_sid = self.map.owner_of_range(r)
        src, dst = self._engines[src_sid], self._engines[dst_sid]
        src_pool, dst_pool = self._pools[src_sid], self._pools[dst_sid]
        s0 = src_pool.stats.snapshot()
        d0 = dst_pool.stats.snapshot()
        m0 = self.meta_pool.stats.snapshot()
        sc0 = src.cache.stats.snapshot()
        dc0 = dst.cache.stats.snapshot()
        sssd0 = src_pool.ssd_dev.stats.snapshot() if src_pool.ssd_dev else None
        dssd0 = dst_pool.ssd_dev.stats.snapshot() if dst_pool.ssd_dev else None

        # --- copy: the source's durable cut. Commit its WAL tail first
        # so the cut covers every applied write, then ship page images
        # (checkpoint-age) and committed WAL records (newer, replayed
        # through dst.put so they land in the target's own WAL *after*
        # the images they supersede — recovery order stays valid).
        self._commit_shard(src_sid)
        page_bytes = wal_bytes = wal_records = 0
        for pid in self._range_pids(r):
            img = src.durable_page_image(pid)
            if img is None:
                continue
            dst.cache.put(pid, img, store=dst.store)
            vc.pages_moved += 1
            page_bytes += int(img.size)
            self._fp("copy:page")
        for key, value in src.committed_wal_records():
            if self.range_of(key) != r:
                continue
            dst.put(key, value)
            wal_records += 1
            wal_bytes += _REC.size + len(value)
            self._fp("copy:wal")
        # --- flush: durable on the target, still unreachable
        dst.cache.writeback(dst.store)
        self._commit_shard(dst_sid)
        self._fp("flush:done")
        # --- ownership record: the atomic per-range commit point
        self.map.record_owner(r, view, dst_sid)
        self._fp("own:committed")
        # --- invalidate: the source durably forgets the range
        for pid in self._range_pids(r):
            src.discard_page(pid)
        self._fp("invalidate:done")

        moved = page_bytes + wal_bytes
        vc.page_bytes += page_bytes
        vc.wal_bytes += wal_bytes
        vc.wal_records_moved += wal_records
        eng = COST_MODEL.engine_time_ns(src_pool.stats.delta(s0),
                                        cache=src.cache.stats.delta(sc0))
        eng += COST_MODEL.engine_time_ns(dst_pool.stats.delta(d0),
                                         cache=dst.cache.stats.delta(dc0),
                                         cluster_transfer_bytes=moved)
        eng += COST_MODEL.engine_time_ns(self.meta_pool.stats.delta(m0))
        if sssd0 is not None:
            eng += SSD_COST_MODEL.time_ns(src_pool.ssd_dev.stats.delta(sssd0))
        if dssd0 is not None:
            eng += SSD_COST_MODEL.time_ns(dst_pool.ssd_dev.stats.delta(dssd0))
        vc.engine_ns += eng
        vc.transfer_ns += COST_MODEL.cluster_transfer_ns(moved)

    def _scrub_all(self) -> None:
        """Discard every non-owner copy of every range — idempotent
        convergence sweep (reopen + view-change tail). Quietly drops
        frames an engine's WAL replay resurrected for keys that migrated
        away, finishes any invalidation a crash interrupted, and fences
        stale WAL residue (below)."""
        owners = self.map.owners()
        owned: Dict[int, set] = {sid: set() for sid in self._engines}
        for r, own_sid in owners.items():
            owned.setdefault(own_sid, set()).add(r)
            for sid, eng in self._engines.items():
                if sid == own_sid:
                    continue
                for pid in self._range_pids(r):
                    eng.discard_page(pid)
        # WAL fence: an engine whose WAL still holds committed records
        # for ranges it does NOT own would replay them unconditionally on
        # a later restart — over newer page images shipped by a re-run
        # copy (the migration target after a crash-interrupted copy) or
        # by a reshard that moves the range back (the migration source,
        # whose records outlive the invalidate step) — reverting
        # committed writes. Checkpoint such engines now: the non-owned
        # frames were dropped above, so the checkpoint flushes only owned
        # data and truncates the stale records away.
        for sid in sorted(self._engines):
            eng = self._engines[sid]
            if any(self.range_of(key) not in owned[sid]
                   for key, _ in eng.committed_wal_records()):
                eng.checkpoint()
