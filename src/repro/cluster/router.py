"""``ClusterKV``: N independent engines behind one durable shard map,
with crash-consistent live view changes.

Each shard is a full :class:`~repro.core.recovery.PersistentKV` engine
on its **own pool** — its own WAL lanes, flush queue, spill tier and
DRAM frames — exactly as ``repro.serve`` builds per-tenant engines. The
router owns no data: it routes every ``put``/``get`` by the durable
per-range ownership record in the :class:`~repro.cluster.shardmap.ShardMap`
(on a small dedicated *meta pool*), so "who answers this key" has a
single point of truth at every instant, including mid-reshard.

**Life of a view change** (``reshard``), per moving range, generalizing
the spill protocol's down-tier-first ordering to cross-shard handoff::

    copy   — durable page images + committed WAL records stream from
             the source engine into the target's frames and WAL
    flush  — the target writes the range back and commits its WAL: the
             bytes are durable on the new owner, but unreachable (the
             ownership record still names the old one)
    own    — ONE Zero-log barrier flips the range's ownership record:
             the atomic per-range commit point
    inval  — the source durably discards its copies (frames, parked
             images, PMem slots, SSD extents)

A crash strictly before ``own`` recovers exactly-old-owner (the copy
never mutated the source); at or after it, exactly-new-owner (the
source's leftovers are unreachable and scrubbed at reopen). Never both,
never neither — the crash-corpus invariant. Resuming an interrupted
view change re-runs only the not-yet-flipped ranges (the copy step is
idempotent: it re-ships the same durable cut) and converges.

Migration traffic is charged on the modeled clock: each range's step
prices the PMem/SSD/cache deltas it caused on *both* engines through
``engine_time_ns`` and adds the interconnect term
``cluster_transfer_ns(bytes_moved)`` on the receiving side, so
``benchmarks/cluster_reshard.py`` can race resharding against
foreground traffic deterministically.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.costmodel import COST_MODEL, SSD_COST_MODEL
from repro.core.recovery import KVConfig, PersistentKV, _REC
from repro.cluster.shardmap import ShardMap

__all__ = ["ClusterConfig", "ClusterKV", "CausalSession", "ReshardReport",
           "ViewChange"]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Shape of a sharded KV: the per-shard engine config plus the range
    geometry of the shard map.

    ``kv.npages`` spans the **global** key space (every engine can host
    any page; which pages it actually materializes is decided by
    ownership), carved into ``n_ranges`` equal page-aligned ranges —
    the granule of migration and of ownership records."""

    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    n_ranges: int = 8
    map_capacity: int = 1 << 14
    #: migration copy verification: ``"auto"``/``"fused"``/``"ref"`` run
    #: one ``apply_unpack`` pass per range (checksum-verify + assemble
    #: the shipped page images in a single device read); ``"staged"``
    #: keeps the per-page host loop. Bytes landed on the target are
    #: identical either way — this only picks how the transfer is
    #: verified and priced.
    kernel_impl: str = "auto"

    def __post_init__(self) -> None:
        if self.n_ranges < 1 or self.kv.npages % self.n_ranges:
            raise ValueError(
                f"n_ranges={self.n_ranges} must divide npages="
                f"{self.kv.npages} (ranges are page-aligned)")

    @property
    def pages_per_range(self) -> int:
        """Pages per migration granule."""
        return self.kv.npages // self.n_ranges

    @property
    def nkeys(self) -> int:
        """Global key space size (== the per-engine key space)."""
        return self.kv.nkeys


@dataclasses.dataclass(frozen=True)
class ReshardReport:
    """What one view change did, on the modeled clock.

    ``engine_ns`` is the full modeled cost of the migration steps (PMem
    + SSD + cache work on both sides, interconnect term included);
    ``transfer_ns`` is the interconnect term alone. ``wall_ns`` is the
    modeled *wall clock*: within each batch of concurrently in-flight
    ranges (``width=`` on ``begin_reshard``), each engine serializes its
    own work but distinct engines overlap, so a batch costs
    max-over-engines (plus the serialized shard-map flips) rather than
    the serial sum. Even at ``width=1`` a range's reader (source) and
    writer (target) pipeline, so ``wall_ns <= engine_ns`` always; the
    win from ``width > 1`` is overlapping *different* src/dst pairs."""

    view: int
    shards: Tuple[int, ...]
    ranges_moved: Tuple[int, ...]
    pages_moved: int
    page_bytes: int
    wal_records_moved: int
    wal_bytes: int
    engine_ns: float
    transfer_ns: float
    wall_ns: float = 0.0

    @property
    def bytes_moved(self) -> int:
        """Total migration payload: page images + WAL records."""
        return self.page_bytes + self.wal_bytes


class ViewChange:
    """One in-flight view change, migrated ``width`` ranges at a time.

    Callers that interleave foreground traffic (the reshard-under-load
    benchmark, a serving loop) drive :meth:`step` themselves; the last
    step commits the view. :meth:`run` drives it to completion.

    ``width > 1`` flights that many ranges concurrently: one
    :meth:`step` runs the batch stage-interleaved — every range's copy,
    then every flush, then every ownership flip, then every invalidate
    — with each range's failpoints firing independently at its own
    protocol points. Per-range ordering (copy < flush < own < inval) is
    exactly the serial protocol's, so the exactly-old-XOR-exactly-new
    crash invariant is untouched and the migrated bytes are identical
    to a ``width=1`` run; only the modeled wall clock changes (distinct
    engines overlap — see ``ReshardReport.wall_ns``)."""

    def __init__(self, cluster: "ClusterKV", shards: Iterable[int], *,
                 width: int = 1) -> None:
        """Durably start the view change toward ``shards`` (re-entrant
        for resume — see ``ShardMap.begin_view``)."""
        ids = tuple(sorted(int(s) for s in shards))
        unknown = set(ids) - set(cluster._engines)
        if unknown:
            raise ValueError(f"no engines for shards {sorted(unknown)}")
        self._c = cluster
        self.width = max(1, int(width))
        self.view = cluster.map.begin_view(ids)
        cluster._fp("view:started")
        self.target_shards = ids
        self.target = cluster.map.assignment(ids)
        #: ranges still to migrate, in range order (deterministic)
        self.todo: List[int] = cluster.map.moving_ranges(ids)
        self.moved: List[int] = []
        self.pages_moved = 0
        self.page_bytes = 0
        self.wal_records_moved = 0
        self.wal_bytes = 0
        self.engine_ns = 0.0
        self.transfer_ns = 0.0
        self.wall_ns = 0.0
        self._done = False

    def step(self) -> bool:
        """Migrate the next batch of up to ``width`` moving ranges
        (commit the view once none remain). Returns True while more
        steps are pending."""
        if self._done:
            return False
        if self.todo:
            batch = self.todo[:self.width]
            del self.todo[:self.width]
            self._c._migrate_batch(batch, self.view, self)
            self.moved.extend(batch)
        if not self.todo:
            self._c._scrub_all()
            self._c.map.commit_view()
            self._c._fp("view:committed")
            self._done = True
            return False
        return True

    def run(self) -> ReshardReport:
        """Drive the view change to completion and report it."""
        while self.step():
            pass
        return self.report()

    def report(self) -> ReshardReport:
        """The migration's byte/time accounting so far."""
        return ReshardReport(
            view=self.view, shards=self.target_shards,
            ranges_moved=tuple(self.moved), pages_moved=self.pages_moved,
            page_bytes=self.page_bytes,
            wal_records_moved=self.wal_records_moved,
            wal_bytes=self.wal_bytes, engine_ns=self.engine_ns,
            transfer_ns=self.transfer_ns, wall_ns=self.wall_ns)


class CausalSession:
    """A client session with cross-shard causal consistency.

    Within a session, before a write lands on a shard every *other*
    shard holding one of the session's earlier-not-yet-committed writes
    is group-committed first. Each shard's WAL recovers a contiguous
    durable prefix, so after any crash a surviving write implies all its
    causal predecessors survive too — across shards, not just within
    one — which is the acceptance suite's causal-chain invariant.
    Reads go through the owners' frames: read-your-writes for free."""

    def __init__(self, cluster: "ClusterKV") -> None:
        """Bind to a router; sessions are cheap, make one per client."""
        self._c = cluster
        self._uncommitted: set = set()

    def put(self, key: int, value: bytes) -> int:
        """Causally ordered durable upsert (see class docstring)."""
        sid = self._c.owner_of(key)
        for dep in sorted(self._uncommitted - {sid}):
            self._c._commit_shard(dep)
            self._uncommitted.discard(dep)
        lsn = self._c.put(key, value)
        self._uncommitted.add(sid)
        return lsn

    def get(self, key: int) -> bytes:
        """Read through the owning engine's frames."""
        return self._c.get(key)

    def flush(self) -> None:
        """Commit every shard this session still has in flight."""
        for sid in sorted(self._uncommitted):
            self._c._commit_shard(sid)
        self._uncommitted.clear()


class ClusterKV:
    """Sharded PersistentKV: route by durable ownership, reshard live.

    Open-or-create over a meta pool (shard map) plus one pool per shard
    (engines, named ``s<sid>`` on their pool). Tiered configs need each
    shard pool's SSD attached **before** construction. ``shards=``
    restricts the *initial view* to a subset of the provided pools —
    spare pools idle until a reshard pulls them in (the add-shard
    scenario). On reopen the constructor recovers every engine and the
    map, then scrubs non-owner leftovers of every range (frames the
    engines' WAL replay resurrected for keys they no longer own, durable
    copies an interrupted invalidation left behind, and — via a
    checkpoint of any engine whose WAL holds records for ranges it does
    not own — stale WAL residue that would otherwise replay over newer
    page images on a later restart) — reopening is therefore
    self-healing, and resuming an interrupted view change is just
    ``resume()``."""

    def __init__(self, meta_pool, shard_pools: Dict[int, object],
                 cfg: Optional[ClusterConfig] = None, *,
                 shards: Optional[Iterable[int]] = None) -> None:
        """Open-or-create; see the class docstring."""
        cfg = cfg or ClusterConfig()
        self.cfg = cfg
        self.meta_pool = meta_pool
        self._pools = dict(sorted(shard_pools.items()))
        if len({id(p) for p in self._pools.values()}) != len(self._pools):
            raise ValueError("each shard needs its own pool")
        #: test-only failpoint hook — called with a protocol point name;
        #: raising aborts mid-protocol exactly like a crash would
        self.failpoints = None
        recover = meta_pool.directory.lookup("sm.hd") is not None
        ids = tuple(sorted(int(s) for s in (shards if shards is not None
                                            else self._pools)))
        if set(ids) - set(self._pools):
            raise ValueError(f"shards {ids} not all backed by pools")
        self.map = ShardMap(meta_pool, n_ranges=cfg.n_ranges,
                            nkeys=cfg.nkeys, shards=ids,
                            map_capacity=cfg.map_capacity)
        if (self.map.n_ranges, self.map.nkeys) != (cfg.n_ranges, cfg.nkeys):
            raise ValueError(
                f"map geometry ({self.map.n_ranges} ranges, "
                f"{self.map.nkeys} keys) does not match the config "
                f"({cfg.n_ranges}, {cfg.nkeys})")
        self._engines: Dict[int, PersistentKV] = {
            sid: pool.kv(f"s{sid}", cfg.kv)
            for sid, pool in self._pools.items()}
        missing = set(self.map.owners().values()) - set(self._engines)
        if missing:
            raise ValueError(f"map names owners {sorted(missing)} but no "
                             f"pool was provided for them")
        if recover:
            self._scrub_all()

    def pool(self, sid: int):
        """The pmem pool backing shard ``sid`` (for pricing its deltas
        through ``engine_time_ns`` and for test assertions)."""
        return self._pools[int(sid)]

    @classmethod
    def open(cls, meta_pool, shard_pools: Dict[int, object],
             cfg: Optional[ClusterConfig] = None) -> "ClusterKV":
        """Reopen after a restart (same as the constructor on existing
        pools — provided for symmetry with ``PersistentKV.open``)."""
        return cls(meta_pool, shard_pools, cfg)

    # ----------------------------------------------------------- failpoint

    def _fp(self, point: str) -> None:
        if self.failpoints is not None:
            self.failpoints(point)

    # -------------------------------------------------------------- sizing

    @staticmethod
    def shard_pool_bytes(cfg: ClusterConfig) -> int:
        """Pool bytes one shard's engine needs (directory included)."""
        return PersistentKV.region_bytes(cfg.kv) + (1 << 14)

    @staticmethod
    def meta_pool_bytes(cfg: ClusterConfig) -> int:
        """Pool bytes the shard map's meta pool needs."""
        from repro.pool import DEFAULT_MAX_REGIONS, Pool
        g = cfg.kv.geometry
        return (Pool.overhead_bytes(g, DEFAULT_MAX_REGIONS)
                + ShardMap.region_bytes(g, cfg.map_capacity) + (1 << 12))

    # ------------------------------------------------------------- routing

    def range_of(self, key: int) -> int:
        """The page-aligned range a key belongs to."""
        if not (0 <= key < self.cfg.nkeys):
            raise KeyError(key)
        return (key // self.cfg.kv.recs_per_page) // self.cfg.pages_per_range

    def owner_of(self, key: int) -> int:
        """The shard whose durable ownership record answers this key."""
        return self.map.owner_of_range(self.range_of(key))

    def engine(self, sid: int) -> PersistentKV:
        """A shard's engine (tests and benchmarks poke at internals)."""
        return self._engines[sid]

    @property
    def view(self) -> int:
        """Last committed view number."""
        return self.map.view

    @property
    def shards(self) -> Tuple[int, ...]:
        """Shard ids of the committed view."""
        return self.map.shards

    def _range_pids(self, r: int) -> range:
        ppr = self.cfg.pages_per_range
        return range(r * ppr, (r + 1) * ppr)

    # ----------------------------------------------------------------- api

    def put(self, key: int, value: bytes) -> int:
        """Durable upsert on the owning shard; returns its engine LSN."""
        return self._engines[self.owner_of(key)].put(key, value)

    def get(self, key: int) -> bytes:
        """Read from the owning shard — exactly one engine ever answers
        a key under a given map state."""
        return self._engines[self.owner_of(key)].get(key)

    def commit(self) -> None:
        """Group-commit every engine's WAL tail."""
        for sid in sorted(self._engines):
            self._commit_shard(sid)

    def checkpoint(self) -> None:
        """Checkpoint every engine (flush + WAL truncation)."""
        for sid in sorted(self._engines):
            self._engines[sid].checkpoint()

    def session(self) -> CausalSession:
        """A causally consistent client session (see CausalSession)."""
        return CausalSession(self)

    def _commit_shard(self, sid: int) -> None:
        commit = getattr(self._engines[sid].wal, "commit", None)
        if commit is not None:
            commit()

    def digest(self) -> str:
        """sha256 over the committed view, every ownership record and
        every key's current value — the bit-determinism witness the
        acceptance suite compares across identically seeded runs."""
        h = hashlib.sha256()
        h.update(struct.pack("<QI", self.map.view, len(self.map.shards)))
        for sid in self.map.shards:
            h.update(struct.pack("<I", sid))
        for r in range(self.cfg.n_ranges):
            h.update(struct.pack("<II", r, self.map.owner_of_range(r)))
        for key in range(self.cfg.nkeys):
            try:
                h.update(self.get(key))
            except KeyError:
                h.update(b"\x00absent")
        return h.hexdigest()

    # -------------------------------------------------------- view changes

    def begin_reshard(self, shards: Iterable[int], *,
                      width: int = 1) -> ViewChange:
        """Durably start a view change toward ``shards`` and hand back
        the step-at-a-time driver. ``width`` is how many ranges each
        step flights concurrently (see ``ViewChange``)."""
        return ViewChange(self, shards, width=width)

    def reshard(self, shards: Iterable[int], *,
                width: int = 1) -> ReshardReport:
        """Run a full view change to ``shards`` (see module docstring
        for the per-range protocol) and report what moved."""
        return self.begin_reshard(shards, width=width).run()

    def resume(self, *, width: int = 1) -> Optional[ReshardReport]:
        """Finish a view change a crash interrupted, if any: re-runs the
        not-yet-flipped ranges and commits. Returns None when no view is
        pending."""
        if self.map.pending is None:
            return None
        return self.reshard(self.map.pending[1], width=width)

    # ----------------------------------------------- migration internals

    def _snap(self, sid: int):
        """Stats snapshot of one shard's pool + cache + SSD (pricing)."""
        pool, eng = self._pools[sid], self._engines[sid]
        return (pool.stats.snapshot(), eng.cache.stats.snapshot(),
                pool.ssd_dev.stats.snapshot() if pool.ssd_dev else None)

    def _price(self, sid: int, snap, *, transfer_bytes: int = 0) -> float:
        """Modeled ns of the work ``sid`` did since ``snap``."""
        pool, eng = self._pools[sid], self._engines[sid]
        p0, c0, d0 = snap
        ns = COST_MODEL.engine_time_ns(pool.stats.delta(p0),
                                       cache=eng.cache.stats.delta(c0),
                                       cluster_transfer_bytes=transfer_bytes)
        if d0 is not None:
            ns += SSD_COST_MODEL.time_ns(pool.ssd_dev.stats.delta(d0))
        return ns

    def _copy_pages(self, src: PersistentKV, dst: PersistentKV, r: int,
                    vc: ViewChange) -> int:
        """Ship one range's durable page images to the target's frames,
        verified. Returns the page bytes moved.

        The fused path (``cfg.kernel_impl != "staged"``) runs ONE
        ``apply_unpack`` pass over the whole range on the receiving
        side: checksum-verify every shipped image against the source's
        per-page popcount summary and assemble them in a single device
        read, instead of a per-page host loop. A mismatch means the
        transfer corrupted a page — raise rather than land bad bytes.
        The landed bytes are identical on both paths."""
        pids: List[int] = []
        imgs: List[np.ndarray] = []
        for pid in self._range_pids(r):
            img = src.durable_page_image(pid)
            if img is None:
                continue
            pids.append(pid)
            imgs.append(np.ascontiguousarray(img, dtype=np.uint8))
        ps = self.cfg.kv.page_size
        if pids and self.cfg.kernel_impl != "staged" and ps % 128 == 0:
            from repro.kernels.apply_unpack import apply_unpack
            packed = np.concatenate([i.reshape(-1) for i in imgs])
            expected = np.array(
                [int(np.unpackbits(i.reshape(-1)).sum()) for i in imgs],
                dtype=np.uint32)
            res = apply_unpack(np.zeros(len(pids) * ps, np.uint8), packed,
                               np.arange(len(pids), dtype=np.int32),
                               expected, block_bytes=ps,
                               impl=self.cfg.kernel_impl)
            if res.nbad:
                raise RuntimeError(
                    f"migration copy of range {r}: checksum mismatch on "
                    f"{res.nbad} of {len(pids)} page image(s)")
            out = np.asarray(res.out)
            imgs = [out[i * ps:(i + 1) * ps] for i in range(len(pids))]
        page_bytes = 0
        for pid, img in zip(pids, imgs):
            dst.cache.put(pid, img, store=dst.store)
            vc.pages_moved += 1
            page_bytes += int(img.size)
            self._fp("copy:page")
        return page_bytes

    def _migrate_batch(self, batch: List[int], view: int,
                       vc: ViewChange) -> None:
        """Migrate a batch of ranges stage-interleaved: every range's
        copy, then every flush, then every ownership flip, then every
        invalidate (each range keeps the module docstring's per-range
        ordering and failpoints, so crash behavior per range is exactly
        the serial protocol's), priced on the modeled clock.

        Wall-clock pricing: each range's work is attributed to the
        engines that did it (source-side ns, target-side ns including
        the interconnect term, shard-map ns). Within the batch one
        engine serializes everything it touches, distinct engines
        overlap — the batch's wall time is the max over engines of
        their summed work, plus the (serialized) shard-map flips."""
        moves = []
        for r in batch:
            moves.append({"r": r, "src": self.map.owner_of_range(r),
                          "dst": vc.target[r], "moved": 0,
                          "ns_src": 0.0, "ns_dst": 0.0, "ns_meta": 0.0})

        # --- copy: each range ships the source's durable cut. Commit
        # the source WAL tail first so the cut covers every applied
        # write, then ship page images (checkpoint-age) and committed
        # WAL records (newer, replayed through dst.put so they land in
        # the target's own WAL *after* the images they supersede —
        # recovery order stays valid).
        for m in moves:
            src, dst = self._engines[m["src"]], self._engines[m["dst"]]
            s0, d0 = self._snap(m["src"]), self._snap(m["dst"])
            self._commit_shard(m["src"])
            page_bytes = self._copy_pages(src, dst, m["r"], vc)
            wal_bytes = wal_records = 0
            for key, value in src.committed_wal_records():
                if self.range_of(key) != m["r"]:
                    continue
                dst.put(key, value)
                wal_records += 1
                wal_bytes += _REC.size + len(value)
                self._fp("copy:wal")
            m["moved"] = page_bytes + wal_bytes
            vc.page_bytes += page_bytes
            vc.wal_bytes += wal_bytes
            vc.wal_records_moved += wal_records
            m["ns_src"] += self._price(m["src"], s0)
            m["ns_dst"] += self._price(m["dst"], d0,
                                       transfer_bytes=m["moved"])
        # --- flush: durable on each target, still unreachable
        for m in moves:
            d0 = self._snap(m["dst"])
            self._engines[m["dst"]].cache.writeback(
                self._engines[m["dst"]].store)
            self._commit_shard(m["dst"])
            self._fp("flush:done")
            m["ns_dst"] += self._price(m["dst"], d0)
        # --- ownership records: the atomic per-range commit points
        for m in moves:
            m0 = self.meta_pool.stats.snapshot()
            self.map.record_owner(m["r"], view, m["dst"])
            self._fp("own:committed")
            m["ns_meta"] += COST_MODEL.engine_time_ns(
                self.meta_pool.stats.delta(m0))
        # --- invalidate: each source durably forgets its range
        for m in moves:
            s0 = self._snap(m["src"])
            for pid in self._range_pids(m["r"]):
                self._engines[m["src"]].discard_page(pid)
            self._fp("invalidate:done")
            m["ns_src"] += self._price(m["src"], s0)

        per_engine: Dict[int, float] = {}
        for m in moves:
            per_engine[m["src"]] = per_engine.get(m["src"], 0.0) + m["ns_src"]
            per_engine[m["dst"]] = per_engine.get(m["dst"], 0.0) + m["ns_dst"]
            vc.engine_ns += m["ns_src"] + m["ns_dst"] + m["ns_meta"]
            vc.transfer_ns += COST_MODEL.cluster_transfer_ns(m["moved"])
        vc.wall_ns += (max(per_engine.values(), default=0.0)
                       + sum(m["ns_meta"] for m in moves))

    def _scrub_all(self) -> None:
        """Discard every non-owner copy of every range — idempotent
        convergence sweep (reopen + view-change tail). Quietly drops
        frames an engine's WAL replay resurrected for keys that migrated
        away, finishes any invalidation a crash interrupted, and fences
        stale WAL residue (below)."""
        owners = self.map.owners()
        owned: Dict[int, set] = {sid: set() for sid in self._engines}
        for r, own_sid in owners.items():
            owned.setdefault(own_sid, set()).add(r)
            for sid, eng in self._engines.items():
                if sid == own_sid:
                    continue
                for pid in self._range_pids(r):
                    eng.discard_page(pid)
        # WAL fence: an engine whose WAL still holds committed records
        # for ranges it does NOT own would replay them unconditionally on
        # a later restart — over newer page images shipped by a re-run
        # copy (the migration target after a crash-interrupted copy) or
        # by a reshard that moves the range back (the migration source,
        # whose records outlive the invalidate step) — reverting
        # committed writes. Checkpoint such engines now: the non-owned
        # frames were dropped above, so the checkpoint flushes only owned
        # data and truncates the stale records away.
        for sid in sorted(self._engines):
            eng = self._engines[sid]
            if any(self.range_of(key) not in owned[sid]
                   for key, _ in eng.committed_wal_records()):
                eng.checkpoint()
