"""Deterministic key-range → shard assignment with durable, versioned
views.

A :class:`ShardMap` partitions the global key space into ``n_ranges``
page-aligned ranges and assigns each range an owning shard by
**rendezvous (highest-random-weight) hashing**: every ``(range, shard)``
pair hashes to a 64-bit weight and the range belongs to the shard with
the largest one. Adding or removing a shard therefore moves only the
ranges whose argmax changed — the minimal-movement property the
resharding acceptance tests assert — and the assignment is a pure
function of the id pair, bit-identical across processes and replays.

Assignment *authority*, however, is never the hash: it is the durable
**ownership record** ``(range, view, shard)``, the single point of
truth for who answers a range at every instant — including halfway
through an interrupted view change, when some ranges have flipped to
the rendezvous target of the new view and the rest still carry their
old owner. The records live in a double-buffered Zero-log pair behind a
two-slot head region, mirroring the spill map's ping-pong protocol
(``repro.tier.spill``): appends are single-barrier Zero-log commits,
and when the active log fills the live record set is rewritten into the
other buffer and the head flipped with one NT store + persist — the
atomic switch. A crash on either side of any barrier recovers a
consistent map.

View *lifecycle* records share the same logs: a genesis record fixes
the range geometry, a **view-start** record durably declares the shard
set a reshard is moving toward (so recovery can resume an interrupted
migration), and a **view-commit** record seals it. Between start and
commit the map is intentionally mixed — each range is old-owner or
new-owner, decided solely by its ownership record — which is exactly
the crash-mid-reshard invariant the corpus asserts.

Layout on the (typically dedicated, small) *meta pool*::

    <name>.m0 / <name>.m1   ping-pong Zero logs of map records
    <name>.hd               2-slot head (counter, active) — max wins
"""

from __future__ import annotations

import struct
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.blocks import align_up
from repro.core.costmodel import FlushKind

__all__ = ["ShardMap", "rendezvous_owner"]

_MASK = (1 << 64) - 1

_GENESIS = struct.Struct("<II")    # n_ranges, nkeys
_VIEWHDR = struct.Struct("<QI")    # view, nshards (start record)
_COMMIT = struct.Struct("<Q")      # view          (commit record)
_OWN = struct.Struct("<IQI")       # range, view, shard
_HD = struct.Struct("<QI")         # counter, active buffer

_T_GENESIS, _T_START, _T_COMMIT, _T_OWN = 1, 2, 3, 4


def _mix(x: int) -> int:
    """splitmix64 finalizer: a full-avalanche 64-bit mix, so rendezvous
    weights are uncorrelated across both range ids and shard ids."""
    x &= _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return (x ^ (x >> 31)) & _MASK


def rendezvous_owner(range_id: int, shards: Iterable[int]) -> int:
    """The highest-random-weight owner of a range among ``shards``.

    Deterministic (pure function of the id pair; ties — p ≈ 2^-64 —
    go to the smaller shard id via the sorted scan with a strict
    comparison) and minimal-movement: removing a shard reassigns only
    the ranges it owned, adding one steals only the ranges whose new
    weight wins."""
    best_sid, best_w = -1, -1
    for sid in sorted(int(s) for s in shards):
        w = _mix(((range_id + 1) << 32) ^ _mix(sid + 0x9E3779B9))
        if w > best_w:
            best_sid, best_w = sid, w
    if best_sid < 0:
        raise ValueError("rendezvous over an empty shard set")
    return best_sid


class ShardMap:
    """Durable, versioned range→shard map (see module docstring).

    Open-or-create on ``pool``: pass ``n_ranges``/``nkeys``/``shards``
    to create (the initial view 1 commits an ownership record for every
    range up front — the map is total from birth), or reopen an
    existing map and recover the committed view, the per-range owners,
    and any view change that was started but never committed. Creation
    is itself crash-recoverable: it is judged complete only once the
    initial view's commit record is durable, so reopening after a crash
    anywhere inside creation (regions allocated but genesis missing, or
    ownership records partly written) re-runs the remainder
    idempotently rather than misreading the pool as corrupt."""

    def __init__(self, pool, *, n_ranges: Optional[int] = None,
                 nkeys: Optional[int] = None,
                 shards: Optional[Iterable[int]] = None,
                 name: str = "sm", map_capacity: int = 1 << 14) -> None:
        """Open-or-create; see the class docstring for the two modes."""
        self.pool = pool
        self.name = name
        cl = pool.geometry.cache_line
        self._hd = pool.raw(f"{name}.hd", nbytes=2 * cl)
        self._maps = []
        for j in (0, 1):
            rname = f"{name}.m{j}"
            if pool.directory.lookup(rname) is not None:
                self._maps.append(pool.log(rname))
            else:
                self._maps.append(pool.log(rname, capacity=int(map_capacity),
                                           technique="zero"))
        self._hd_counter, self._active = self._read_hd()

        #: committed geometry (genesis record)
        self.n_ranges: int = 0
        self.nkeys: int = 0
        #: last committed view number
        self.view: int = 0
        #: ``(view, shard ids)`` started but not committed, else None
        self.pending: Optional[Tuple[int, Tuple[int, ...]]] = None
        self._view_shards: Dict[int, Tuple[int, ...]] = {}
        self._owner: Dict[int, Tuple[int, int]] = {}   # range -> (view, sid)
        for raw in self._maps[self._active].recovered.entries:
            self._replay(bytes(raw))

        # Creation is detected from the recovered *record* state, not
        # from region presence: the head/log regions come into being
        # before the genesis record does, so a crash during creation can
        # leave the regions allocated with the records partly (or not at
        # all) appended. Reopening such a pool re-runs creation
        # idempotently instead of misreading it as a corrupt map.
        if self.n_ranges == 0:
            # no durable genesis: a fresh map, or a creation the crash
            # cut before its first record — (re-)create from scratch
            if not n_ranges or not nkeys or not shards:
                raise ValueError(
                    "creating a ShardMap needs n_ranges, nkeys and shards")
            self._append(bytes([_T_GENESIS])
                         + _GENESIS.pack(int(n_ranges), int(nkeys)))
        if self.view == 0:
            # the initial view never committed: creation was interrupted
            # somewhere between genesis and the view-1 commit. Finish it
            # idempotently — ``begin_view`` is re-entrant, ranges whose
            # ownership record already landed keep it (same rendezvous
            # answer), the rest get theirs now. The log's prefix
            # guarantee makes the commit record the creation barrier: if
            # it recovered, every record before it did too.
            if self.pending is not None:
                ids = self.pending[1]
            elif shards:
                ids = tuple(sorted(int(s) for s in shards))
            else:
                raise ValueError(
                    f"shard map {self.name!r} creation was interrupted "
                    f"before its shard set became durable; pass shards= "
                    f"to re-create it")
            view = self.begin_view(ids)
            for r in range(self.n_ranges):
                if r not in self._owner:
                    self.record_owner(r, view, rendezvous_owner(r, ids))
            self.commit_view()

    # ------------------------------------------------------ durable layer

    def _read_hd(self) -> Tuple[int, int]:
        img = self._hd.durable_view()
        cl = self.pool.geometry.cache_line
        best = (0, 0)
        for slot in range(2):
            counter, active = _HD.unpack_from(img, slot * cl)
            if counter > best[0]:
                best = (counter, active)
        return best

    def _write_hd(self, active: int) -> None:
        self._hd_counter += 1
        slot = self._hd_counter % 2
        cl = self.pool.geometry.cache_line
        self._hd.store(slot * cl, _HD.pack(self._hd_counter, active),
                       streaming=True)
        self._hd.persist(slot * cl, _HD.size, kind=FlushKind.NT)
        self._active = active

    def _replay(self, raw: bytes) -> None:
        t, body = raw[0], raw[1:]
        if t == _T_GENESIS:
            self.n_ranges, self.nkeys = _GENESIS.unpack_from(body)
        elif t == _T_START:
            view, n = _VIEWHDR.unpack_from(body)
            ids = tuple(struct.unpack_from(f"<{n}I", body, _VIEWHDR.size))
            self._view_shards[view] = ids
            self.pending = (view, ids)
        elif t == _T_COMMIT:
            (view,) = _COMMIT.unpack_from(body)
            self.view = view
            if self.pending is not None and self.pending[0] == view:
                self.pending = None
        elif t == _T_OWN:
            r, view, sid = _OWN.unpack_from(body)
            cur = self._owner.get(r)
            if cur is None or view >= cur[0]:
                self._owner[r] = (view, sid)

    def _append(self, raw: bytes) -> None:
        try:
            self._maps[self._active].append(raw)
        except RuntimeError:
            # Compaction itself can overflow (the live set alone no
            # longer fits a buffer); surface that exactly like a
            # post-compaction append failure — one capacity diagnostic,
            # not the log's generic error. Durably benign either way:
            # the head only flips after a complete rewrite.
            try:
                self._compact()
                self._maps[self._active].append(raw)
            except RuntimeError:
                raise RuntimeError(
                    f"shard map {self.name!r} cannot hold its live record "
                    f"set even after compaction ({self.n_ranges} ranges); "
                    f"create it with a larger map_capacity") from None
        self._replay(raw)

    def _compact(self) -> None:
        """Rewrite the live state — genesis, committed view, pending
        view (if any), one ownership record per range — into the
        inactive log, then flip the head (the atomic switch)."""
        other = 1 - self._active
        log = self._maps[other]
        log.reset()
        log.append(bytes([_T_GENESIS])
                   + _GENESIS.pack(self.n_ranges, self.nkeys))
        ids = self._view_shards.get(self.view, ())
        log.append(self._start_record(self.view, ids))
        log.append(bytes([_T_COMMIT]) + _COMMIT.pack(self.view))
        if self.pending is not None:
            log.append(self._start_record(*self.pending))
        for r in sorted(self._owner):
            view, sid = self._owner[r]
            log.append(bytes([_T_OWN]) + _OWN.pack(r, view, sid))
        self._write_hd(other)

    @staticmethod
    def _start_record(view: int, ids: Tuple[int, ...]) -> bytes:
        return (bytes([_T_START]) + _VIEWHDR.pack(view, len(ids))
                + struct.pack(f"<{len(ids)}I", *ids))

    # -------------------------------------------------------------- reads

    @property
    def shards(self) -> Tuple[int, ...]:
        """Shard ids of the last *committed* view."""
        return self._view_shards.get(self.view, ())

    def owner_of_range(self, r: int) -> int:
        """The shard durably recorded as owning range ``r`` right now —
        the routing authority, even mid-reshard."""
        try:
            return self._owner[int(r)][1]
        except KeyError:
            raise RuntimeError(f"range {r} has no ownership record "
                               f"(corrupt or foreign map)") from None

    def owners(self) -> Dict[int, int]:
        """``{range: owning shard}`` from the durable records."""
        return {r: sid for r, (_, sid) in sorted(self._owner.items())}

    def assignment(self, shards: Optional[Iterable[int]] = None
                   ) -> Dict[int, int]:
        """The pure rendezvous assignment for a shard set (default: the
        committed view's) — where a reshard *would* put every range."""
        ids = tuple(sorted(int(s) for s in shards)) if shards is not None \
            else self.shards
        return {r: rendezvous_owner(r, ids) for r in range(self.n_ranges)}

    def moving_ranges(self, shards: Iterable[int]) -> List[int]:
        """Ranges whose durable owner differs from the rendezvous target
        under ``shards`` — what a reshard to that set must migrate."""
        target = self.assignment(shards)
        return [r for r in range(self.n_ranges)
                if target[r] != self.owner_of_range(r)]

    # ------------------------------------------------------- view changes

    def begin_view(self, shards: Iterable[int]) -> int:
        """Durably start a view change toward ``shards`` and return its
        view number. Re-entrant for resume: beginning the *same* target
        again returns the pending view without a new record; a different
        target while one is pending is an error (finish or resume it
        first)."""
        ids = tuple(sorted(int(s) for s in shards))
        if not ids:
            raise ValueError("a view needs at least one shard")
        if self.pending is not None:
            if self.pending[1] == ids:
                return self.pending[0]
            raise RuntimeError(
                f"view {self.pending[0]} -> {self.pending[1]} is still "
                f"pending; resume it before starting another")
        view = self.view + 1
        self._append(self._start_record(view, ids))
        return view

    def record_owner(self, r: int, view: int, sid: int) -> None:
        """Durably flip range ``r`` to ``sid`` under ``view`` — one
        Zero-log barrier, the per-range commit point of a migration."""
        self._append(bytes([_T_OWN]) + _OWN.pack(int(r), int(view), int(sid)))

    def commit_view(self) -> None:
        """Durably seal the pending view: it becomes the committed one
        and routing answers for it alone."""
        if self.pending is None:
            raise RuntimeError("no view change in progress")
        self._append(bytes([_T_COMMIT]) + _COMMIT.pack(self.pending[0]))

    # -------------------------------------------------------------- sizing

    @staticmethod
    def region_bytes(geometry, map_capacity: int = 1 << 14) -> int:
        """Meta-pool bytes the map's regions need (directory excluded)."""
        return (2 * (int(map_capacity) + geometry.block)
                + align_up(2 * geometry.cache_line, geometry.block))
