"""Unified model: segments of scanned block stacks covering all families.

``init_params`` works under ``jax.eval_shape`` (abstract init for the
dry-run). ``forward`` serves training (full-seq, optional remat), prefill
(full-seq returning caches), and decode (S=1 against caches). Caches are
pytrees stacked along the scan axis, so the same ``lax.scan`` drives both
parameter-only (train) and parameter+cache (serve) traversals.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    gqa_apply,
    gqa_cache_init,
    gqa_init,
    mla_apply,
    mla_cache_init,
    mla_init,
)
from repro.models.config import ModelConfig, Segment
from repro.models.layers import (
    embed_apply,
    embed_init,
    ffn_apply,
    ffn_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    unembed_apply,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rec_apply, rec_init, rec_state_init
from repro.models.ssd import ssd_apply, ssd_init, ssd_state_init

Params = Dict[str, Any]


# ========================================================================
# block init / apply
# ========================================================================


def _attn_init(key, cfg, dtype):
    if cfg.attn_kind == "mla":
        return mla_init(key, cfg, dtype=dtype)
    return gqa_init(key, cfg, dtype=dtype)


def init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    if kind in ("attn", "enc"):
        return {"norm1": rmsnorm_init(D, dtype), "attn": _attn_init(ks[0], cfg, dtype),
                "norm2": rmsnorm_init(D, dtype), "ffn": ffn_init(ks[1], D, cfg.d_ff, dtype, cfg.ffn_kind)}
    if kind == "attn_moe":
        return {"norm1": rmsnorm_init(D, dtype), "attn": _attn_init(ks[0], cfg, dtype),
                "norm2": rmsnorm_init(D, dtype), "moe": moe_init(ks[1], cfg, dtype=dtype)}
    if kind == "rec":
        return {"norm1": rmsnorm_init(D, dtype), "rec": rec_init(ks[0], cfg, dtype=dtype),
                "norm2": rmsnorm_init(D, dtype), "ffn": ffn_init(ks[1], D, cfg.d_ff, dtype, cfg.ffn_kind)}
    if kind == "ssd":
        return {"norm1": rmsnorm_init(D, dtype), "ssd": ssd_init(ks[0], cfg, dtype=dtype)}
    if kind == "xattn":
        return {"norm1": rmsnorm_init(D, dtype), "attn": gqa_init(ks[0], cfg, dtype=dtype),
                "norm2": rmsnorm_init(D, dtype), "xatt": gqa_init(ks[1], cfg, dtype=dtype),
                "norm3": rmsnorm_init(D, dtype), "ffn": ffn_init(ks[2], D, cfg.d_ff, dtype, cfg.ffn_kind)}
    raise ValueError(kind)


def block_cache_init(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype, enc_len: int = 0):
    if kind in ("attn", "attn_moe"):
        if cfg.attn_kind == "mla":
            return mla_cache_init(cfg, batch, max_len, dtype)
        return gqa_cache_init(cfg, batch, max_len, dtype)
    if kind == "rec":
        return rec_state_init(cfg, batch, dtype)
    if kind == "ssd":
        return ssd_state_init(cfg, batch, dtype)
    if kind == "xattn":
        self_c = gqa_cache_init(cfg, batch, max_len, dtype)
        hd, KV = cfg.raw_head_dim, cfg.padded_kv_heads
        cross = {"k": jnp.zeros((batch, enc_len, KV, hd), dtype),
                 "v": jnp.zeros((batch, enc_len, KV, hd), dtype)}
        return {"self": self_c, "cross": cross}
    if kind == "enc":
        return None
    raise ValueError(kind)


def apply_block(kind: str, p: Params, x: jax.Array, *, cfg: ModelConfig,
                positions, enc_out=None, cache=None, cache_pos=None):
    eps = cfg.norm_eps
    new_cache = None
    if kind in ("attn", "attn_moe", "enc"):
        h = rmsnorm(x, p["norm1"], eps)
        if cfg.attn_kind == "mla" and kind != "enc":
            a, new_cache = mla_apply(p["attn"], h, cfg=cfg, positions=positions,
                                     cache=cache, cache_pos=cache_pos)
        else:
            a, new_cache = gqa_apply(
                p["attn"], h, cfg=cfg, positions=positions,
                causal=(kind != "enc"), window=cfg.window if kind != "enc" else 0,
                cache=cache, cache_pos=cache_pos)
        x = x + a
        h = rmsnorm(x, p["norm2"], eps)
        if kind == "attn_moe":
            x = x + moe_apply(p["moe"], h, cfg)
        else:
            x = x + ffn_apply(p["ffn"], h)
        return x, new_cache
    if kind == "rec":
        h = rmsnorm(x, p["norm1"], eps)
        a, new_cache = rec_apply(p["rec"], h, cfg=cfg, state=cache)
        x = x + a
        x = x + ffn_apply(p["ffn"], rmsnorm(x, p["norm2"], eps))
        return x, new_cache
    if kind == "ssd":
        h = rmsnorm(x, p["norm1"], eps)
        a, new_cache = ssd_apply(p["ssd"], h, cfg=cfg, state=cache)
        return x + a, new_cache
    if kind == "xattn":
        sc = cache["self"] if cache is not None else None
        cc = cache["cross"] if cache is not None else None
        h = rmsnorm(x, p["norm1"], eps)
        a, new_self = gqa_apply(p["attn"], h, cfg=cfg, positions=positions,
                                causal=True, cache=sc, cache_pos=cache_pos)
        x = x + a
        h = rmsnorm(x, p["norm2"], eps)
        a, new_cross = gqa_apply(p["xatt"], h, cfg=cfg, positions=positions,
                                 cross=True, kv_input=enc_out, cache=cc)
        x = x + a
        x = x + ffn_apply(p["ffn"], rmsnorm(x, p["norm3"], eps))
        return x, {"self": new_self, "cross": new_cross}
    raise ValueError(kind)


# ========================================================================
# segments
# ========================================================================


def init_segment(key, seg: Segment, cfg: ModelConfig, dtype) -> Params:
    def unit_init(k):
        ks = jax.random.split(k, len(seg.pattern))
        return {f"b{i}": init_block(ks[i], kind, cfg, dtype)
                for i, kind in enumerate(seg.pattern)}
    keys = jax.random.split(key, seg.repeat)
    return jax.vmap(unit_init)(keys)


def segment_cache_init(seg: Segment, cfg: ModelConfig, batch: int, max_len: int,
                       dtype, enc_len: int = 0):
    def one():
        return {f"b{i}": block_cache_init(kind, cfg, batch, max_len, dtype, enc_len)
                for i, kind in enumerate(seg.pattern)}
    unit = one()
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (seg.repeat,) + a.shape).copy(), unit)


def apply_segment(seg: Segment, p: Params, x, *, cfg, positions, enc_out=None,
                  caches=None, cache_pos=None, remat: bool = False):
    from repro.distributed.autoshard import constrain

    # residual-stream layout: batch over data axes; optionally sequence-
    # sharded over `model` (SP) so the L scan-carried/remat-saved copies
    # shrink by the TP degree. Without an explicit constraint SPMD
    # propagation picks pathological layouts for scan carries (observed:
    # D over data, batch replicated).
    res_spec = ("fsdp", "model" if cfg.seq_shard_activations else None, None)

    def body(carry, xs):
        x = carry
        x = constrain(x, res_spec)
        lp, lc = xs
        new_cs = {}
        for i, kind in enumerate(seg.pattern):
            c = None if lc is None else lc.get(f"b{i}")
            x, nc = apply_block(kind, lp[f"b{i}"], x, cfg=cfg, positions=positions,
                                enc_out=enc_out, cache=c, cache_pos=cache_pos)
            new_cs[f"b{i}"] = nc
        x = constrain(x, res_spec)
        return x, (new_cs if caches is not None else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (p, caches) if caches is not None else (p, None)
    if caches is None:
        x, _ = jax.lax.scan(lambda c, lp: body(c, (lp, None)), x, p)
        return x, None
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


# ========================================================================
# model
# ========================================================================


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4 + len(cfg.segments) + len(cfg.encoder_segments))
    params: Params = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype)
    params["decoder"] = {
        f"seg{i}": init_segment(ks[4 + i], seg, cfg, dtype)
        for i, seg in enumerate(cfg.segments)
    }
    if cfg.encoder_segments:
        off = 4 + len(cfg.segments)
        params["encoder"] = {
            f"seg{i}": init_segment(ks[off + i], seg, cfg, dtype)
            for i, seg in enumerate(cfg.encoder_segments)
        }
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


def _positions_for(cfg: ModelConfig, batch: Dict[str, jax.Array], S: int):
    if "positions" in batch:
        return batch["positions"]
    B = batch["tokens"].shape[0]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           *, remat: bool = False) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (B, S_enc, D)."""
    x = frames
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    for i, seg in enumerate(cfg.encoder_segments):
        x, _ = apply_segment(seg, params["encoder"][f"seg{i}"], x, cfg=cfg,
                             positions=pos, remat=remat)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    caches: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (logits (B,S,V_padded), new_caches or None)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens)
    if "vis_embeds" in batch:
        # VLM stub frontend: patch embeddings occupy the first S_vis slots
        ve = batch["vis_embeds"].astype(x.dtype)
        S_vis = ve.shape[1]
        pad = jnp.zeros((B, S - S_vis, ve.shape[2]), dtype=x.dtype)
        vis_full = jnp.concatenate([ve, pad], axis=1)
        is_vis = (jnp.arange(S) < S_vis)[None, :, None]
        x = jnp.where(is_vis, vis_full, x)
    enc_out = None
    if "frames" in batch:
        enc_out = encode(params, cfg, batch["frames"].astype(x.dtype),
                         remat=remat)
    positions = _positions_for(cfg, batch, S) if cache_pos is None else None
    if cache_pos is not None:
        pos = jnp.broadcast_to(jnp.reshape(cache_pos, (1, 1)).astype(jnp.int32), (B, S))
        positions = jnp.broadcast_to(pos[None], (3, B, S)) if cfg.mrope_sections else pos

    new_caches: Dict[str, Any] = {}
    for i, seg in enumerate(cfg.segments):
        c = None if caches is None else caches[f"seg{i}"]
        x, nc = apply_segment(seg, params["decoder"][f"seg{i}"], x, cfg=cfg,
                              positions=positions, enc_out=enc_out,
                              caches=c, cache_pos=cache_pos, remat=remat)
        new_caches[f"seg{i}"] = nc
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed_apply(head, x)
    return logits, (new_caches if caches is not None else None)


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            *, remat: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _ = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    mask = (labels >= 0)
    loss = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return loss, {"loss": loss}


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                enc_len: int = 0) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    return {
        f"seg{i}": segment_cache_init(seg, cfg, batch, max_len, dtype, enc_len)
        for i, seg in enumerate(cfg.segments)
    }


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches: Params, cache_pos: jax.Array,
                extras: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Params]:
    """One serving step: tokens (B,1) + caches @ cache_pos → logits, caches."""
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    logits, new_caches = forward(params, cfg, batch, caches=caches,
                                 cache_pos=cache_pos)
    return logits, new_caches
