"""Unified model configuration covering all assigned architecture families.

A model is a sequence of *segments*; each segment is a scanned stack of a
repeating *pattern unit* of blocks (1 block for uniform archs, e.g. 3 for
RecurrentGemma's rec/rec/attn cycle). ``jax.lax.scan`` over stacked unit
params keeps HLO size O(unique blocks), which is what makes 60-layer 236B
configs lowerable.

TPU-alignment padding (recorded per arch in the config, asserted in tests):
- ``vocab_size`` padded to a multiple of 256 (sharded over the 16-way
  ``model`` axis),
- ``num_heads`` padded up to a multiple of 16 when tensor-parallel heads
  require it (56→64 for deepseek-coder, 28→32 qwen2-vl, 20→32 whisper,
  24→32 mamba2 SSD heads). Real frameworks (MaxText, Megatron) do the same;
  padded heads are dead weight the roofline analysis accounts as overhead.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["ModelConfig", "Segment", "pad_to"]


def pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class Segment:
    """A scanned stack: ``pattern`` (block kinds of one unit) × ``repeat``."""

    pattern: Tuple[str, ...]   # e.g. ("attn",), ("rec","rec","attn"), ("ssd",)
    repeat: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads (pre-padding)

    # --- attention -------------------------------------------------------
    attn_kind: str = "gqa"         # gqa | mla
    window: int = 0                # >0: local (sliding-window) attention
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) halves

    # --- MLA (deepseek-v2) -------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0    # leading layers with dense FFN
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256               # SSD chunk length

    # --- RG-LRU (recurrentgemma) ---------------------------------------------
    lru_width: int = 0
    block_pattern: Tuple[str, ...] = ()   # cycle, e.g. ("rec","rec","attn")

    # --- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0

    # --- frontends (stubs per assignment) ----------------------------------------
    frontend: str = "none"         # none | audio_frames | vision_patches

    # --- misc ----------------------------------------------------------------
    ffn_kind: str = "swiglu"       # swiglu | gelu (whisper's plain MLP)
    #: sequence parallelism for the residual stream: shard the scan-carried
    #: activations (and their remat-saved copies) along S over `model`.
    #: Trades per-layer all-gathers for L× smaller activation memory.
    seq_shard_activations: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_pad: int = 256
    tp_heads_multiple: int = 16    # pad heads so TP over model axis divides

    # ------------------------------------------------------------------ props

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad)

    @property
    def raw_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_heads(self) -> int:
        return pad_to(self.num_heads, self.tp_heads_multiple)

    @property
    def padded_kv_heads(self) -> int:
        # KV heads: shard over model axis when divisible, else replicate.
        # If q-heads were padded, keep the q/kv group ratio an integer.
        if self.num_kv_heads == self.num_heads:
            return self.padded_heads
        return self.num_kv_heads

    @property
    def padded_ssm_heads(self) -> int:
        return pad_to(self.ssm_heads, self.tp_heads_multiple) if self.ssm_heads else 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def segments(self) -> Tuple[Segment, ...]:
        """Decoder segments (encoder handled separately for enc-dec)."""
        if self.family == "ssm":
            return (Segment(("ssd",), self.num_layers),)
        if self.block_pattern:
            unit = len(self.block_pattern)
            full = self.num_layers // unit
            rem = self.num_layers - full * unit
            segs = [Segment(tuple(self.block_pattern), full)]
            if rem:
                segs.append(Segment(tuple(self.block_pattern[:rem]), 1))
            return tuple(segs)
        if self.family == "moe" and self.first_dense_layers:
            return (
                Segment(("attn",), self.first_dense_layers),
                Segment(("attn_moe",), self.num_layers - self.first_dense_layers),
            )
        if self.family == "moe":
            return (Segment(("attn_moe",), self.num_layers),)
        if self.family == "audio":
            return (Segment(("xattn",), self.num_layers),)  # decoder w/ cross
        return (Segment(("attn",), self.num_layers),)

    @property
    def encoder_segments(self) -> Tuple[Segment, ...]:
        if not self.encoder_layers:
            return ()
        return (Segment(("enc",), self.encoder_layers),)

    # ------------------------------------------------------------- counting

    def param_count(self) -> int:
        """Analytic parameter count (unpadded dims; used for 6·N·D roofline)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        hd = self.raw_head_dim

        def attn_params() -> int:
            if self.attn_kind == "mla":
                q = (self.q_lora_rank and
                     D * self.q_lora_rank
                     + self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                     ) or D * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                kv = D * (self.kv_lora_rank + self.qk_rope_dim)
                kv += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                o = self.num_heads * self.v_head_dim * D
                return q + kv + o
            q = D * self.num_heads * hd
            kv = 2 * D * self.num_kv_heads * hd
            o = self.num_heads * hd * D
            return q + kv + o

        def dense_ffn() -> int:
            return (2 if self.ffn_kind == "gelu" else 3) * D * F

        def moe_ffn() -> int:
            e = self.num_experts * 3 * D * self.moe_d_ff
            e += self.num_shared_experts * 3 * D * self.moe_d_ff
            e += D * self.num_experts  # router
            return e

        def rec_block() -> int:
            # Griffin recurrent block: two input branches D→W, temporal conv,
            # RG-LRU gates (2 × W×W), Λ, and the output projection W→D.
            W = self.lru_width or D
            return 2 * D * W + self.conv_kernel * W + 2 * W * W + W + W * D

        def ssd_block() -> int:
            di, H, N = self.d_inner, self.ssm_heads, self.ssm_state
            return D * 2 * di + D * 2 * N + D * H + self.conv_kernel * di + di * D

        # count by iterating logical layers
        count = 0
        for seg in self.segments:
            for _ in range(seg.repeat):
                for kind in seg.pattern:
                    if kind == "attn":
                        count += attn_params() + dense_ffn() + 2 * D
                    elif kind == "attn_moe":
                        count += attn_params() + moe_ffn() + 2 * D
                    elif kind == "rec":
                        count += rec_block() + dense_ffn() + 2 * D
                    elif kind == "ssd":
                        count += ssd_block() + 2 * D
                    elif kind == "xattn":
                        count += 2 * attn_params() + dense_ffn() + 3 * D
                    elif kind == "enc":
                        count += attn_params() + dense_ffn() + 2 * D
        total += count
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                total += attn_params() + dense_ffn() + 2 * D
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        inactive = (self.num_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = self.num_layers - self.first_dense_layers
        return full - n_moe_layers * inactive
