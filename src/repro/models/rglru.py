"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

The RG-LRU is a gated diagonal linear recurrence
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ u_t),
    a_t = exp(−c · softplus(Λ) ⊙ r_t),      r_t, i_t = σ(gates(u_t))
Training/prefill uses ``jax.lax.associative_scan`` over time (log-depth on
TPU — this is the sub-quadratic mixer that makes long_500k viable);
decode is a single O(width) state update.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init

_C = 8.0  # Griffin's recurrence-sharpness constant


def rec_init(key, cfg, *, dtype) -> Params:
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 6)
    lam = jax.random.uniform(ks[4], (W,), minval=0.9, maxval=0.999)
    # Λ parameterized so softplus(Λ_raw) gives the target decay band
    lam_raw = jnp.log(jnp.expm1(-jnp.log(lam) / _C))
    return {
        "wx": dense_init(ks[0], D, W, dtype),
        "wg": dense_init(ks[1], D, W, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_kernel, W)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "wa": dense_init(ks[3], W, W, dtype),
        "wi": dense_init(ks[5], W, W, dtype),
        "lam": lam_raw.astype(jnp.float32),
        "wo": dense_init(ks[2], W, D, dtype, scale=1.0 / math.sqrt(W)),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. u (B,S,W); w (k,W).
    Returns (out, new_conv_state (B,k-1,W))."""
    B, S, W = u.shape
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((B, k - 1, W), dtype=u.dtype)
    else:
        pad = conv_state
    full = jnp.concatenate([pad, u], axis=1)          # (B, S+k-1, W)
    out = jnp.zeros_like(u)
    for j in range(k):
        out = out + full[:, j : j + S, :] * w[j]
    new_state = full[:, -(k - 1):, :] if k > 1 else jnp.zeros((B, 0, W), u.dtype)
    return out + b, new_state


def rec_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state = {"h": (B,W) f32, "conv": (B,k-1,W)}; None → zeros (train)."""
    B, S, D = x.shape
    u = x @ p["wx"]
    g = jax.nn.gelu(x @ p["wg"])
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid((u @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["wi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # (B,S,W) f32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = mult * i * u.astype(jnp.float32)

    h0 = state["h"] if state is not None else None
    if S == 1 and h0 is not None:
        h = a[:, 0] * h0 + b[:, 0]                     # decode step
        hs = h[:, None]
    else:
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0)
        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, bl * ar + br
        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hs[:, -1]

    y = (hs.astype(x.dtype) * g) @ p["wo"]
    return y, {"h": h, "conv": new_conv}


def rec_state_init(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    W = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, W), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, W), dtype=dtype),
    }
