"""Shared neural building blocks (self-contained functional style).

Params are nested dicts of jnp arrays. Every ``init_*`` has a matching
``apply``-style function; init works under ``jax.eval_shape`` so the
dry-run never materializes weights.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ RMSNorm

def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- RoPE

def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) int → cos/sin (..., dim/2) in f32."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., H, hd) rotated pairwise; cos/sin broadcastable (..., hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(dt)


def mrope_angles(positions: jax.Array, dim: int, theta: float,
                 sections: Sequence[int]) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE. positions (3, B, S) — temporal / height / width ids.
    ``sections`` split the dim/2 frequency bands among the 3 position kinds
    (text tokens carry identical ids in all three → reduces to 1-D RoPE)."""
    assert positions.shape[0] == len(sections) == 3
    half = dim // 2
    assert sum(sections) == half
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(theta) / half))
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3, B, S, half)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, ..., start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


# ------------------------------------------------------------------- SwiGLU

def ffn_init(key, d: int, f: int, dtype, kind: str = "swiglu") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "gelu":
        return {"up": dense_init(k2, d, f, dtype),
                "down": dense_init(k3, f, d, dtype)}
    return {
        "gate": dense_init(k1, d, f, dtype),
        "up": dense_init(k2, d, f, dtype),
        "down": dense_init(k3, f, d, dtype),
    }


def ffn_apply(p: Params, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------- embedding

def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def embed_apply(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed_apply(table: jax.Array, x: jax.Array) -> jax.Array:
    return x @ table.T


# ------------------------------------------------------------------- loss

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean masked token cross-entropy in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
