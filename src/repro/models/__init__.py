"""Model definitions: unified multi-family transformer/SSM stack."""

from repro.models.config import ModelConfig, Segment  # noqa: F401
from repro.models.model import (  # noqa: F401
    decode_step,
    encode,
    forward,
    init_caches,
    init_params,
    lm_loss,
)
