"""Mamba-2 block via SSD (state-space duality, arXiv:2405.21060).

The selective SSM  h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,  y_t = C_t·h_t
is computed with the chunked SSD algorithm: within chunks of length Q the
recurrence is materialized as a masked quadratic form (MXU-friendly),
between chunks only the (H, P, N) states are passed through a scan —
O(S·Q + S·N·P) work, sub-quadratic in S, constant-memory decode.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


def _dims(cfg):
    H = cfg.padded_ssm_heads
    P = cfg.ssm_head_dim
    return H, P, H * P, cfg.ssm_state


def ssd_init(key, cfg, *, dtype) -> Params:
    H, P, di, N = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], D, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, di + 2 * N)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "w_out": dense_init(ks[2], di, D, dtype, scale=1.0 / math.sqrt(di)),
    }


def _conv_causal(u, w, b, state):
    B, S, C = u.shape
    k = w.shape[0]
    pad = state if state is not None else jnp.zeros((B, k - 1, C), u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = jnp.zeros_like(u)
    for j in range(k):
        out = out + full[:, j : j + S, :] * w[j]
    return jax.nn.silu(out + b), full[:, -(k - 1):, :]


def ssd_apply(
    p: Params,
    x_in: jax.Array,
    *,
    cfg,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """state = {"h": (B,H,P,N) f32, "conv": (B,k-1,di+2N)}."""
    B, S, D = x_in.shape
    H, P, di, N = _dims(cfg)
    proj = x_in @ p["w_in"]
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _conv_causal(xBC, p["conv_w"], p["conv_b"], conv_state)
    x, B_, C_ = jnp.split(xBC, [di, di + N], axis=-1)
    x = x.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    dA = dt * A                                                       # (B,S,H) ≤ 0
    Bx = B_.astype(jnp.float32)
    Cx = C_.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    h0 = state["h"] if state is not None else None
    if S == 1 and h0 is not None:
        # ------------------------- decode step ---------------------------
        decay = jnp.exp(dA[:, 0])                                     # (B,H)
        h = decay[..., None, None] * h0 + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xf[:, 0], Bx[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", Cx[:, 0], h)
        y = y + p["D_skip"][:, None] * xf[:, 0]
        ys = y.reshape(B, 1, di)
    else:
        # ---------------------- chunked SSD scan -------------------------
        Q = min(cfg.chunk, S)
        assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
        nc = S // Q
        dAc = dA.reshape(B, nc, Q, H)
        cum = jnp.cumsum(dAc, axis=2)                                 # (B,c,Q,H)
        total = cum[:, :, -1]                                         # (B,c,H)
        xc = xf.reshape(B, nc, Q, H, P)
        Bc = Bx.reshape(B, nc, Q, N)
        Cc = Cx.reshape(B, nc, Q, N)
        dtc = dt.reshape(B, nc, Q, H)

        # intra-chunk quadratic form
        scores = jnp.einsum("bcqn,bctn->bcqt", Cc, Bc)                # (B,c,Q,Q)
        decay_qt = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])   # (B,c,Q,Q,H)
        causal = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        w_qt = scores[..., None] * decay_qt * dtc[:, :, None] * causal[None, None, :, :, None]
        y_intra = jnp.einsum("bcqth,bcthp->bcqhp", w_qt, xc)

        # chunk end-states
        endw = jnp.exp(total[:, :, None] - cum) * dtc                 # (B,c,Q,H)
        chunk_state = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", endw, xc, Bc)

        # inter-chunk recurrence over nc chunks
        decay_chunk = jnp.exp(total)                                  # (B,c,H)
        def combine(l, r):
            al, sl = l
            ar, sr = r
            return al * ar, sl * ar[..., None, None] + sr
        _, states = jax.lax.associative_scan(
            combine, (decay_chunk, chunk_state), axis=1)              # zero-init
        if h0 is not None:
            cumdecay = jnp.cumprod(decay_chunk, axis=1)               # (B,c,H)
            states = states + cumdecay[..., None, None] * h0[:, None]
        first = (h0[:, None] if h0 is not None
                 else jnp.zeros((B, 1, H, P, N)))
        prev = jnp.concatenate([first, states[:, :-1]], axis=1)      # (B,c,H,P,N)
        y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                             Cc, jnp.exp(cum), prev)
        y = (y_intra + y_inter).reshape(B, S, H, P)
        y = y + p["D_skip"][None, None, :, None] * xf
        ys = y.reshape(B, S, di)
        h = states[:, -1]

    out = ys.astype(x_in.dtype) * jax.nn.silu(z)
    out = rmsnorm(out, p["norm"], cfg.norm_eps)
    return out @ p["w_out"], {"h": h, "conv": new_conv}


def ssd_state_init(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    H, P, di, N = _dims(cfg)
    return {
        "h": jnp.zeros((batch, H, P, N), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * N), dtype=dtype),
    }
