"""Mixture-of-Experts block: top-k routing with sort-based token dispatch.

Dispatch strategy (the scalable one — no (N, E, C) one-hot cube and no
all-experts-on-all-tokens waste): replicate each token k times, stably sort
the (N·k) assignments by expert id, compute each assignment's position
inside its expert group, drop beyond a fixed per-expert capacity
C = N·k/E·capacity_factor, and scatter into an (E·C, D) buffer. Expert FFNs
then run as one batched einsum over the leading (sharded) expert dimension;
results gather back through the same permutation with router-gate weighting.
Compute is k·cf·N·D·F — proportional to *active* parameters, which keeps
the MODEL_FLOPS/HLO_FLOPS roofline ratio honest.

Sharding: expert dim → ``model`` axis (EP), capacity dim → data axes; the
scatter from token-sharded to expert-sharded layout is XLA's all-to-all.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, ffn_apply, ffn_init
from repro.distributed.autoshard import constrain


def moe_init(key, cfg, *, dtype) -> Params:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "gate": jax.random.normal(ks[1], (E, D, F), dtype=jnp.float32).astype(dtype) / (D ** 0.5),
        "up": jax.random.normal(ks[2], (E, D, F), dtype=jnp.float32).astype(dtype) / (D ** 0.5),
        "down": jax.random.normal(ks[3], (E, F, D), dtype=jnp.float32).astype(dtype) / (F ** 0.5),
    }
    if cfg.num_shared_experts:
        p["shared"] = ffn_init(ks[4], D, cfg.num_shared_experts * F, dtype)
    return p


def moe_apply(p: Params, x: jax.Array, cfg) -> jax.Array:
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, D)

    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)            # (N, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    flat_e = experts.reshape(-1)                         # (N·k,)
    order = jnp.argsort(flat_e, stable=True)
    tok = order // k                                     # token of each slot
    sorted_e = flat_e[order]

    cap = max(8, int(round(N * k / E * cfg.capacity_factor)))
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * cap + pos, E * cap)

    # gather tokens into sorted order FIRST (result stays token-sharded),
    # then scatter to the expert-sharded buffer — a single layout change
    # instead of a fused gather+scatter that SPMD lowers to full-buffer
    # all-reduces of partial results.
    x_sorted = constrain(jnp.take(xf, tok, axis=0), ("fsdp", None))
    buf = jnp.zeros((E * cap + 1, D), dtype=x.dtype)
    buf = buf.at[slot].set(x_sorted)
    h = buf[: E * cap].reshape(E, cap, D)
    h = constrain(h, ("model", "fsdp", None))

    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", h, p["up"])
    act = constrain(act, ("model", "fsdp", None))
    y_e = jnp.einsum("ecf,efd->ecd", act, p["down"])
    y_e = constrain(y_e, ("model", "fsdp", None))

    y_flat = jnp.concatenate(
        [y_e.reshape(E * cap, D), jnp.zeros((1, D), dtype=y_e.dtype)], axis=0)
    per_slot = jnp.take(y_flat, slot, axis=0)            # (N·k, D) sorted order
    per_slot = constrain(per_slot.astype(x.dtype), ("fsdp", None))
    # combine WITHOUT a scatter-add: invert the sort permutation so slot j of
    # token n sits at index n·k+j, then reduce over k with an einsum. A
    # scatter-add into (N, D) lowers to all-reduce traffic across the mesh;
    # the gather+einsum form keeps the reduction local to each token's shard.
    inv = jnp.argsort(order)
    per_tok = jnp.take(per_slot, inv, axis=0).reshape(N, k, D)
    per_tok = constrain(per_tok, ("fsdp", None, None))
    keep_tok = jnp.take(keep, inv, axis=0).reshape(N, k)
    w = (gates * keep_tok).astype(per_tok.dtype)
    y = constrain(jnp.einsum("nkd,nk->nd", per_tok, w), ("fsdp", None))

    if "shared" in p:
        y = y + ffn_apply(p["shared"], xf)
    return y.reshape(B, S, D)


def moe_aux_loss(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style): E·mean(f_e · p_e)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, experts = jax.lax.top_k(probs, cfg.top_k)
    counts = jnp.sum(jax.nn.one_hot(experts, cfg.num_experts, dtype=jnp.float32),
                     axis=(0, 1))
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    imp = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * imp)
