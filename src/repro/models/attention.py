"""Attention variants: GQA (causal / bidirectional / sliding-window / cross)
and MLA (DeepSeek-V2 multi-head latent attention), with decode caches.

Sliding-window attention uses the chunked band formulation (each
window-sized query chunk attends to itself + the previous chunk) so the
score tensor is O(S·2w) instead of O(S²) — this is what makes
recurrentgemma's 32k prefill and 500k decode shapes sub-quadratic, and it
is exact for a (i-w, i] window. Window decode caches are ring buffers.

MLA decode uses the *absorbed* formulation: queries are projected into the
512-d latent space and attention runs directly against the compressed
cache (ckv, k_rope) — the cache stays (S, kv_lora + rope) per sequence
with no per-head storage, DeepSeek-V2's core serving win.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Params,
    apply_rope,
    dense_init,
    mrope_angles,
    rmsnorm,
    rmsnorm_init,
    rope_angles,
)

# =========================================================================
# GQA
# =========================================================================


def gqa_init(key, cfg, *, dtype) -> Params:
    D, hd = cfg.d_model, cfg.raw_head_dim
    H, KV = cfg.padded_heads, cfg.padded_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, D, H * hd, dtype),
        "wk": dense_init(k2, D, KV * hd, dtype),
        "wv": dense_init(k3, D, KV * hd, dtype),
        "wo": dense_init(k4, H * hd, D, dtype, scale=1.0 / math.sqrt(H * hd)),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _rope_for(cfg, positions: jax.Array, hd: int):
    if cfg.mrope_sections:
        return mrope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, hd, cfg.rope_theta)


def _attend(q, k, v, mask, scale):
    # q (B,S,KV,G,hd) k (B,T,KV,hd) v (B,T,KV,hv)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


#: sequences longer than this never materialize (S, T) score tensors
FLASH_THRESHOLD = 2048


def _attend_flash(q, k, v, *, causal: bool, scale: float,
                  k_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanned over key chunks (flash-style).

    Memory is O(S · k_chunk) per head instead of O(S · T): this is what
    makes 32k prefill and 4k MLA training lowerable at production batch
    sizes. (A Pallas splash-attention kernel would additionally skip
    fully-masked blocks; the scan computes them — counted as padding
    overhead in the roofline.)
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    hv = v.shape[-1]
    k_chunk = min(k_chunk, T)
    if T % k_chunk != 0:
        k_chunk = T  # fallback; callers pass power-of-two lengths
    nk = T // k_chunk
    qf = q.astype(jnp.float32)
    kc = k.reshape(B, nk, k_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, KV, hv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S)[:, None]

    def body(carry, xs):
        m, l, acc = carry
        kcb, vcb, idx = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qf, kcb.astype(jnp.float32)) * scale
        if causal:
            kpos = idx * k_chunk + jnp.arange(k_chunk)[None, :]
            s = jnp.where((kpos <= qpos)[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vcb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, S), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), dtype=jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, hv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B,S,KV,G,hv)


def gqa_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    positions: jax.Array,            # (B,S) or (3,B,S) for M-RoPE
    causal: bool = True,
    window: int = 0,
    cross: bool = False,                    # cross-attention block
    kv_input: Optional[jax.Array] = None,   # encoder output (None in decode)
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,  # scalar int32: decode position
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = x.shape
    hd = cfg.raw_head_dim
    H, KV = cfg.padded_heads, cfg.padded_kv_heads
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    q = _split_heads(x @ p["wq"], H)

    if cross:
        # cross attention: keys/values from the encoder output at prefill,
        # from the cross cache during decode (no rope, no mask)
        if kv_input is not None:
            k = _split_heads(kv_input @ p["wk"], KV)
            v = _split_heads(kv_input @ p["wv"], KV)
        else:
            k, v = cache["k"], cache["v"]
        qg = q.reshape(B, S, KV, G, hd)
        T = k.shape[1]
        if max(S, T) > FLASH_THRESHOLD:
            out = _attend_flash(qg, k, v, causal=False, scale=scale)
        else:
            mask = jnp.ones((B, KV, G, S, T), dtype=bool)
            out = _attend(qg, k, v, mask, scale)
        out = out.reshape(B, S, H * hd)
        return out @ p["wo"], {"k": k, "v": v}

    cos, sin = _rope_for(cfg, positions, hd)
    q = apply_rope(q, cos, sin)
    k_new = _split_heads(x @ p["wk"], KV)
    k_new = apply_rope(k_new, cos, sin)
    v_new = _split_heads(x @ p["wv"], KV)

    if cache is not None and cache_pos is not None:
        # ---------------- decode: S == 1, append into cache ----------------
        T = cache["k"].shape[1]
        if window:
            slot = jnp.mod(cache_pos, T)
        else:
            slot = cache_pos
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        kv_pos = cache.get("pos")
        if kv_pos is None:
            kv_pos = jnp.arange(T, dtype=jnp.int32)[None, :]
        if window:
            pos_written = jnp.where(
                jnp.arange(T, dtype=jnp.int32)[None, :] == slot,
                cache_pos.astype(jnp.int32), kv_pos)
            age = cache_pos.astype(jnp.int32) - pos_written
            valid = (age >= 0) & (age < window) & (pos_written >= 0)
        else:
            pos_written = kv_pos
            valid = jnp.arange(T, dtype=jnp.int32)[None, :] <= cache_pos
        qg = q.reshape(B, S, KV, G, hd)
        mask = valid[:, None, None, None, :]
        out = _attend(qg, k, v, jnp.broadcast_to(mask, (B, KV, G, S, k.shape[1])),
                      scale).reshape(B, S, H * hd)
        new_cache = {"k": k, "v": v, "pos": pos_written if window else kv_pos}
        return out @ p["wo"], new_cache

    # ---------------------- full-sequence (train / prefill) -----------------
    k, v = k_new, v_new
    if window and S > window and S % window == 0:
        # chunked band attention: O(S · 2w) scores
        nc = S // window
        qc = q.reshape(B, nc, window, H, hd)
        kc = k.reshape(B, nc, window, KV, hd)
        vc = v.reshape(B, nc, window, KV, hd)
        k_prev = jnp.concatenate(
            [jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
        v_prev = jnp.concatenate(
            [jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
        kk = jnp.concatenate([k_prev, kc], axis=2)   # (B,nc,2w,KV,hd)
        vv = jnp.concatenate([v_prev, vc], axis=2)
        qg = qc.reshape(B, nc, window, KV, G, hd)
        scores = jnp.einsum("bcskgd,bctkd->bckgst", qg, kk).astype(jnp.float32) * scale
        qpos = jnp.arange(window)[:, None]
        kpos = jnp.arange(2 * window)[None, :] - window
        band = (kpos <= qpos) & (qpos - kpos < window)
        first_chunk_valid = kpos >= 0
        mask = jnp.where(
            (jnp.arange(nc) == 0)[:, None, None],
            band & first_chunk_valid, band)        # (nc, w, 2w)
        scores = jnp.where(mask[None, :, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bckgst,bctkd->bcskgd", probs, vv)
        out = out.reshape(B, S, H * hd)
        return out @ p["wo"], {"k": k, "v": v}

    qg = q.reshape(B, S, KV, G, hd)
    if not window and S > FLASH_THRESHOLD:
        out = _attend_flash(qg, k, v, causal=causal, scale=scale)
        out = out.reshape(B, S, H * hd)
        return out @ p["wo"], {"k": k, "v": v}
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    if causal:
        m = kpos <= qpos
        if window:
            m &= (qpos - kpos) < window
    else:
        m = jnp.ones((S, S), dtype=bool)
    mask = jnp.broadcast_to(m[None, None, None], (B, KV, G, S, S))
    out = _attend(qg, k, v, mask, scale).reshape(B, S, H * hd)
    return out @ p["wo"], {"k": k, "v": v}


def gqa_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    hd, KV = cfg.raw_head_dim, cfg.padded_kv_heads
    size = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, size, KV, hd), dtype=dtype),
        "v": jnp.zeros((batch, size, KV, hd), dtype=dtype),
        "pos": jnp.full((1, size), -1, dtype=jnp.int32),
    }


# =========================================================================
# MLA (DeepSeek-V2)
# =========================================================================


def mla_init(key, cfg, *, dtype) -> Params:
    D = cfg.d_model
    H = cfg.padded_heads
    nope, rope, hv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lq, lkv = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": dense_init(ks[0], D, lkv + rope, dtype),
        "kv_norm": rmsnorm_init(lkv, dtype),
        "wkv_b": dense_init(ks[1], lkv, H * (nope + hv), dtype),
        "wo": dense_init(ks[2], H * hv, D, dtype, scale=1.0 / math.sqrt(H * hv)),
    }
    if lq:
        p["wq_a"] = dense_init(ks[3], D, lq, dtype)
        p["q_norm"] = rmsnorm_init(lq, dtype)
        p["wq_b"] = dense_init(ks[4], lq, H * (nope + rope), dtype)
    else:
        p["wq"] = dense_init(ks[5], D, H * (nope + rope), dtype)
    return p


def _mla_q(p: Params, x: jax.Array, cfg) -> jax.Array:
    H = cfg.padded_heads
    if "wq_a" in p:
        cq = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = cq @ p["wq_b"]
    else:
        q = x @ p["wq"]
    b, s, _ = q.shape
    return q.reshape(b, s, H, cfg.qk_nope_dim + cfg.qk_rope_dim)


def mla_apply(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = x.shape
    H = cfg.padded_heads
    nope, rope_d, hv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lkv = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = _mla_q(p, x, cfg)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_angles(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = x @ p["wkv_a"]
    ckv_new = rmsnorm(kv_a[..., :lkv], p["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(kv_a[..., None, lkv:], cos, sin)[:, :, 0]  # shared head

    wkv_b = p["wkv_b"].reshape(lkv, H, nope + hv)
    w_k = wkv_b[..., :nope]      # (lkv, H, nope)
    w_v = wkv_b[..., nope:]      # (lkv, H, hv)

    if cache is not None and cache_pos is not None:
        # ---------- absorbed decode: attend in the latent space ----------
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, cache_pos, 0))
        k_r = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, cache_pos, 0))
        T = ckv.shape[1]
        # q_nope absorbed through w_k: (B,1,H,nope) x (lkv,H,nope) -> latent
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_k)
        scores = (
            jnp.einsum("bshl,btl->bhst", q_lat, ckv)
            + jnp.einsum("bshr,btr->bhst", q_rope, k_r)
        ).astype(jnp.float32) * scale
        valid = jnp.arange(T, dtype=jnp.int32)[None, :] <= cache_pos
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        lat = jnp.einsum("bhst,btl->bshl", probs, ckv)
        out = jnp.einsum("bshl,lhv->bshv", lat, w_v).reshape(B, S, H * hv)
        return out @ p["wo"], {"ckv": ckv, "k_rope": k_r}

    # --------------------- train / prefill (materialized) -------------------
    kv = jnp.einsum("btl,lhx->bthx", ckv_new,
                    wkv_b)                        # (B,T,H,nope+hv)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_new[:, :, None, :], (B, S, H, rope_d))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    if S > FLASH_THRESHOLD:
        # flash path via the shared helper (KV := H, group := 1)
        out = _attend_flash(qq[:, :, :, None, :].reshape(B, S, H, 1, nope + rope_d),
                            k, v, causal=True, scale=scale)
        out = out.reshape(B, S, H * hv)
        return out @ p["wo"], {"ckv": ckv_new, "k_rope": k_rope_new}
    scores = jnp.einsum("bshd,bthd->bhst", qq, k).astype(jnp.float32) * scale
    m = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    scores = jnp.where(m[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthv->bshv", probs, v).reshape(B, S, H * hv)
    return out @ p["wo"], {"ckv": ckv_new, "k_rope": k_rope_new}


def mla_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype=dtype),
    }
