"""whisper-large-v3 [audio] — encoder-decoder backbone, conv frontend stub.

32L decoder (+32L encoder) d_model=1280 20H (MHA, head_dim 64) d_ff=5120
vocab=51866 (padded 51968). Heads padded 20→32 for TP. ``input_specs()``
provides precomputed mel-frame embeddings (post-conv features) per the
assignment. [arXiv:2212.04356; unverified]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    encoder_layers=32,
    ffn_kind="gelu",
    frontend="audio_frames",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder_layers=2, tp_heads_multiple=1, vocab_pad=16)
