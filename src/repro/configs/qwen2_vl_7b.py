"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=3584 28H (GQA kv=4, head_dim 128) d_ff=18944 vocab=152064.
Heads padded 28→32 for TP. The vision tower is a stub per the assignment:
``input_specs()`` provides precomputed patch embeddings + M-RoPE position
ids (t/h/w sections 16/24/24 of the 64 rotary half-dims).
[arXiv:2409.12191; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    mrope_sections=(16, 24, 24),
    frontend="vision_patches",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2vl-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        mrope_sections=(4, 6, 6), tp_heads_multiple=1, vocab_pad=16)
