"""codeqwen1.5-7b [dense] — qwen1.5-arch (MHA).

32L d_model=4096 32H (kv=32, head_dim 128) d_ff=13440 vocab=92416.
[hf:Qwen/CodeQwen1.5-7B; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92_416,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="codeqwen-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        tp_heads_multiple=1, vocab_pad=16)
