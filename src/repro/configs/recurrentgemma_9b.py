"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (GQA kv=1, head_dim 256) d_ff=12288 vocab=256000,
lru_width=4096, local window 2048. [arXiv:2402.19427; unverified]
Sub-quadratic (recurrence + sliding window) → runs long_500k.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    window=2048,
    lru_width=4096,
    block_pattern=("rec", "rec", "attn"),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", num_layers=6, d_model=128,
        num_heads=2, num_kv_heads=1, head_dim=64, d_ff=256, vocab_size=512,
        lru_width=128, window=32, tp_heads_multiple=1, vocab_pad=16)
