"""Assigned-architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (the exact published configuration) and
``reduced()`` (a small same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "recurrentgemma_9b",
    "phi35_moe_42b",
    "deepseek_v2_236b",
    "tinyllama_1_1b",
    "stablelm_12b",
    "codeqwen15_7b",
    "deepseek_coder_33b",
    "mamba2_130m",
    "qwen2_vl_7b",
    "whisper_large_v3",
]

#: assignment-sheet name → module id
ALIASES: Dict[str, str] = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "stablelm-12b": "stablelm_12b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
