"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400; q_lora=1536,
qk_nope=128, qk_rope=64, v_head=128; first layer dense (d_ff 12288).
[arXiv:2405.04434; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,              # dense FFN width (first layer)
    vocab_size=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=160,
    top_k=6,
    moe_d_ff=1536,
    num_shared_experts=2,
    first_dense_layers=1,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-smoke", num_layers=3, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
        v_head_dim=32, num_experts=8, top_k=2, moe_d_ff=64,
        num_shared_experts=1, first_dense_layers=1,
        tp_heads_multiple=1, vocab_pad=16)
