"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768, d_inner=1536 (expand 2), ssm_state=128, head_dim 64
(→24 SSD heads, padded to 32 for TP), vocab=50280 (padded to 50432).
Sub-quadratic → runs long_500k. [arXiv:2405.21060; unverified]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_heads=24,
    ssm_head_dim=64,
    expand=2,
    conv_kernel=4,
    chunk=256,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", num_layers=4, d_model=64,
        ssm_state=16, ssm_heads=4, ssm_head_dim=16, vocab_size=512,
        chunk=16, tp_heads_multiple=1, vocab_pad=16)
