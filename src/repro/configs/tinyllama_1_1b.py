"""tinyllama-1.1b [dense] — llama2-arch small.

22L d_model=2048 32H (GQA kv=4, head_dim 64) d_ff=5632 vocab=32000.
[arXiv:2401.02385; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32_000,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="tinyllama-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        tp_heads_multiple=1, vocab_pad=16)
