"""stablelm-12b [dense].

40L d_model=5120 32H (GQA kv=8, head_dim 160) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-12b; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="stablelm-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        tp_heads_multiple=1, vocab_pad=16)
