"""deepseek-coder-33b [dense] — llama-arch.

62L d_model=7168 56H (GQA kv=8, head_dim 128) d_ff=19200 vocab=32256.
Heads padded 56→64 for 16-way tensor parallelism (dead-weight heads are
counted as padding overhead in the roofline). [arXiv:2401.14196; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32_256,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="dscoder-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        tp_heads_multiple=1, vocab_pad=16)
