"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2.

32L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff(expert)=6400
vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32_064,
    num_experts=16,
    top_k=2,
    moe_d_ff=6400,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi35-moe-smoke", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        num_experts=4, top_k=2, moe_d_ff=256, tp_heads_multiple=1, vocab_pad=16)
